"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run JSON artifacts."""
import json
import sys


def table(path, mesh_label):
    rows = json.load(open(path))
    out = []
    out.append(f"\n#### Mesh {mesh_label}\n")
    out.append("| arch | shape | layout | m | compile | mem/dev | t_comp | "
               "t_mem | t_coll | bottleneck | MODEL/HLO | roofline |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"— | — | skipped (full attention @500k) | — | — |")
            continue
        if r.get("error"):
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | | | |")
            continue
        p = r["pcfg"]
        out.append(
            f"| {r['arch']} | {r['shape']} | p{p['pipe']}×t{p['tp']} | "
            f"{p['n_micro']} | {r['compile_s']}s | "
            f"{r['memory_per_device']/2**30:.1f}G | "
            f"{r['t_compute']*1e3:.0f}ms | {r['t_memory']*1e3:.0f}ms | "
            f"{r['t_collective']*1e3:.0f}ms | {r['bottleneck']} | "
            f"{r['useful_ratio']:.3f} | **{r['roofline_fraction']:.3f}** |")
    return "\n".join(out)


if __name__ == "__main__":
    print(table("results/dryrun_sp.json", "16×16 (single pod, 256 chips)"))
    print(table("results/dryrun_mp.json", "2×16×16 (multi-pod, 512 chips)"))
