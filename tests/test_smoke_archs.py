"""Per-architecture smoke tests (assignment requirement): reduced configs of
the same family, one train step + prefill + decode on CPU, asserting output
shapes and finiteness.  The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim


def make_batch(model, shape, key):
    out = {}
    for k, v in model.input_specs(shape).items():
        kk = jax.random.fold_in(key, len(k))
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(kk, v.shape, 0, model.arch.vocab)
        else:
            out[k] = (jax.random.normal(kk, v.shape) * 0.1).astype(v.dtype)
    return out


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke_train_and_serve(name):
    arch = configs.smoke_arch(name)
    pcfg = configs.smoke_parallel(name)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    shape = ShapeConfig("smoke", seq_len=16, global_batch=4, kind="train")
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    opt = optim.init(ocfg, params)
    with set_mesh(mesh):
        step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))
        batch = make_batch(model, shape, key)
        losses = []
        for _ in range(3):
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all(), f"{name}: non-finite loss {losses}"
        assert losses[-1] < losses[0], f"{name}: loss not decreasing {losses}"

        # prefill + one decode step
        pshape = ShapeConfig("p", seq_len=16, global_batch=4, kind="prefill")
        pf = jax.jit(steps.build_prefill_step(model, pcfg, mesh, pshape))
        cache = model.init_cache(pshape, pcfg.n_micro, filled=False)
        pbatch = {k: v for k, v in batch.items() if k != "labels"}
        logits, cache = pf(params, cache, pbatch)
        assert logits.shape == (4, 1, arch.vocab)
        assert bool(jnp.isfinite(logits).all()), f"{name}: prefill NaN"

        dshape = ShapeConfig("d", seq_len=16, global_batch=4, kind="decode")
        sv = jax.jit(steps.build_serve_step(model, pcfg, mesh, dshape))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = sv(params, cache, tok)
        assert logits2.shape == (4, 1, arch.vocab)
        assert bool(jnp.isfinite(logits2).all()), f"{name}: decode NaN"


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The FULL (non-reduced) configs carry the assigned dimensions."""
    a = configs.get_arch(name)
    expect = {
        "whisper-tiny": (4, 384, 1536, 51865),
        "smollm-360m": (32, 960, 2560, 49152),
        "gemma-2b": (18, 2048, 16384, 256000),
        "llama3-405b": (126, 16384, 53248, 128256),
        "deepseek-7b": (30, 4096, 11008, 102400),
        "rwkv6-1.6b": (24, 2048, 7168, 65536),
        "dbrx-132b": (40, 6144, 10752, 100352),
        "mixtral-8x7b": (32, 4096, 14336, 32000),
        "pixtral-12b": (40, 5120, 14336, 131072),
        "hymba-1.5b": (32, 1600, 5504, 32001),
    }[name]
    assert (a.n_layers, a.d_model, a.d_ff, a.vocab) == expect
    pc = configs.get_parallel(name)
    assert pc.pipe * pc.tp == 16, "model axis must factor into pipe x tp"
    if name == "dbrx-132b":
        assert a.moe.n_experts == 16 and a.moe.top_k == 4
    if name == "mixtral-8x7b":
        assert a.moe.n_experts == 8 and a.moe.top_k == 2
        assert a.attn.kind == "swa" and a.attn.window == 4096
    if name == "hymba-1.5b":
        assert a.ssm.state_dim == 16 and a.attn.global_layers
    if name == "gemma-2b":
        assert a.attn.n_kv_heads == 1 and a.attn.head_dim == 256
    if name == "llama3-405b":
        assert a.attn.n_heads == 128 and a.attn.n_kv_heads == 8


def test_param_counts_in_range():
    """Total parameters land near the names on the tin (sanity on configs)."""
    expect = {"smollm-360m": (0.30e9, 0.45e9),
              "gemma-2b": (2.0e9, 3.2e9),
              "llama3-405b": (390e9, 420e9),
              "deepseek-7b": (6e9, 8e9),
              "rwkv6-1.6b": (1.2e9, 2.2e9),
              "mixtral-8x7b": (44e9, 50e9),
              "dbrx-132b": (125e9, 140e9),
              "pixtral-12b": (11e9, 14e9),
              "hymba-1.5b": (0.9e9, 2.0e9)}
    for name, (lo, hi) in expect.items():
        n = configs.get_arch(name).total_params()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_optimized_parallel_variants():
    """§Perf-hillclimbed layouts stay legal tilings of the model axis."""
    for name in configs.ARCH_NAMES:
        p = configs.get_parallel(name, optimized=True)
        assert p.pipe * p.tp * p.dp2 == 16
    d = configs.get_parallel("deepseek-7b", optimized=True)
    assert d.gather_weights_once and d.stream_inputs
    w = configs.get_parallel("whisper-tiny", optimized=True)
    assert w.dp2 == 4 and w.pipe == 2
    l3 = configs.get_parallel("llama3-405b", optimized=True)
    assert l3.remat_layers
