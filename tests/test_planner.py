"""Automatic planner: spec API, budget invariant, BENCH dominance, bitwise.

The two ISSUE-6 tripwires live here as properties:
  (a) planner-predicted peak memory never exceeds the hardware budget its
      chosen plan declared;
  (b) on every BENCH_schedules.json row, the planner's top choice has
      device-model step time <= the hand-picked config for that row.
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.configs.base import (ParallelConfig, PlanSpec, ScheduleSpec,
                                ShapeConfig, parse_schedule)
from repro.core import balance as B
from repro.core import plan as plan_lib
from repro.core.stage import pad_layout, partition_layout
from repro.launch import steps
from repro.planner import (HardwareSpec, PlanReport, plan_profile,
                           profile_arch, profile_unet, score_candidate)
from repro.planner.smoke import _row_spec

BENCH = os.path.join(os.path.dirname(__file__), "..",
                     "BENCH_schedules.json")


# ---------------------------------------------------------------------------
# Structured spec API (satellite 1)
# ---------------------------------------------------------------------------

def test_schedule_spec_roundtrip_and_shim():
    for s in ("gpipe", "1f1b", "zb", "interleaved:3", "gpipe_tasked"):
        spec = ScheduleSpec.from_string(s)
        assert spec.name == s
        assert ScheduleSpec.from_dict(spec.to_dict()) == spec
        assert parse_schedule(s) == (spec.base, spec.virtual_stages)
    with pytest.raises(ValueError, match="virtual"):
        ScheduleSpec.from_string("interleaved:0")
    with pytest.raises(ValueError):
        ScheduleSpec(base="nope")


def test_plan_spec_roundtrip_and_apply():
    spec = PlanSpec(
        schedule=ScheduleSpec(base="zb", residuals="reuse", executor="mpmd"),
        pipe=4, microbatches=8, partition=(2, 1, 1, 0))
    assert PlanSpec.from_dict(spec.to_dict()) == spec
    base = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=2)
    pcfg = spec.apply_to(base)
    hand = ParallelConfig(pipe=4, tp=1, data=1, pod=1, n_micro=8,
                          schedule="zb", residuals="reuse", executor="mpmd",
                          partition=(2, 1, 1, 0))
    assert pcfg == hand
    assert pcfg.spec == spec


def test_parallel_config_validates_partition():
    with pytest.raises(ValueError, match="partition"):
        ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=2,
                       partition=(1, 2, 3))
    ok = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=2,
                        partition=[3, 1])
    assert ok.partition == (3, 1)


# ---------------------------------------------------------------------------
# Partitioned stage layout (satellite 3)
# ---------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_partition_layout_matches_legacy_uniform(n_layers, n_stages):
    L, mask = pad_layout(n_layers, n_stages)
    lay = partition_layout(n_layers, n_stages)
    assert lay.L_per_stage == L
    assert np.array_equal(lay.mask, mask)
    assert sum(lay.sizes) == n_layers
    # flat front-to-back fill: slot (s, l) holds layer s*L + l
    for s in range(n_stages):
        for l in range(lay.sizes[s]):
            assert lay.slot_layer[s, l] == s * L + l


@given(st.integers(2, 24), st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_partition_layout_balanced(n_layers, n_stages):
    sizes = B.block_partition([1.0] * n_layers, n_stages)
    lay = partition_layout(n_layers, n_stages, sizes)
    assert lay.sizes == tuple(sizes)
    # every real layer appears exactly once, contiguously per stage
    seen = sorted(int(x) for x in lay.slot_layer.reshape(-1) if x >= 0)
    assert seen == list(range(n_layers))
    for s in range(n_stages):
        lo, hi = lay.bounds[s], lay.bounds[s + 1]
        assert list(lay.slot_layer[s, :lay.sizes[s]]) == list(range(lo, hi))
        if lay.sizes[s]:
            assert lay.stage_of(lo) == s


def test_stage_partition_wires_balance():
    arch = configs.smoke_arch("smollm-360m")
    pcfg = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=2)
    for by in ("flops", "size"):
        part = steps.stage_partition(arch, pcfg, by=by, seq_len=64)
        assert len(part) == 2 and sum(part) == arch.n_layers
    with pytest.raises(ValueError, match="objective"):
        steps.stage_partition(arch, pcfg, by="vibes")


def test_arch_layer_costs_encdec():
    arch = configs.smoke_arch("whisper-tiny")
    flops, pbytes = B.arch_layer_costs(arch, 64)
    assert len(flops) == arch.enc_layers + arch.n_layers
    # decoder layers carry the cross-attention extra
    assert min(pbytes[arch.enc_layers:]) > max(pbytes[:arch.enc_layers])


# ---------------------------------------------------------------------------
# Hardware spec (tentpole input)
# ---------------------------------------------------------------------------

def test_hardware_yaml_roundtrip(tmp_path):
    text = ("name: test-8\nranks: 8\nmemory_bytes: 1073741824\n"
            "flops: 1.0e12\nici_bytes_per_s: 1.0e9\n")
    p = tmp_path / "hardware.yaml"
    p.write_text(text)
    hw = HardwareSpec.from_yaml(str(p))
    assert (hw.name, hw.ranks) == ("test-8", 8)
    assert hw == HardwareSpec.from_dict(hw.to_dict())
    from repro.planner.hardware import _parse_flat_yaml
    flat = _parse_flat_yaml(text)
    assert HardwareSpec.from_dict(flat) == hw
    with pytest.raises(ValueError, match="unknown"):
        HardwareSpec.from_dict({"ranks": 2, "warp_drive": 9})


def test_plan_cost_uniform_weights_match_default():
    pc0 = plan_lib.plan_cost("1f1b", 6, 3)
    pc1 = plan_lib.plan_cost("1f1b", 6, 3, stage_weights=[1.0, 1.0, 1.0])
    assert pc0.t_end == pytest.approx(pc1.t_end)
    assert pc0.park == pc1.park and pc0.resid == pc1.resid


# ---------------------------------------------------------------------------
# Tripwire (a): hypothesis budget invariant
# ---------------------------------------------------------------------------

@given(st.integers(1, 3).map(lambda k: 2 ** k),     # ranks 2/4/8
       st.integers(20, 34),                         # log2 memory budget
       st.sampled_from(["smollm-360m", "whisper-tiny"]),
       st.integers(3, 5).map(lambda k: 2 ** k))     # global batch
@settings(max_examples=12, deadline=None)
def test_planner_respects_memory_budget(ranks, logmem, arch_name, batch):
    arch = configs.smoke_arch(arch_name)
    shape = ShapeConfig("smoke", 64, batch, "train")
    hw = HardwareSpec(ranks=ranks, memory_bytes=float(2 ** logmem))
    report = plan_profile(profile_arch(arch, shape), hw,
                          shape_name=shape.name,
                          microbatches=[m for m in (1, 2, 4, batch)
                                        if batch % m == 0])
    for c in report.candidates:
        if c.feasible:
            assert max(c.mem_bytes) <= hw.memory_bytes
    best = report.best
    if best is not None:
        assert best.feasible
        assert max(best.mem_bytes) <= hw.memory_bytes
    else:
        assert all(not c.feasible for c in report.candidates)


def test_planner_report_json_roundtrip():
    arch = configs.smoke_arch("smollm-360m")
    shape = ShapeConfig("smoke", 64, 8, "train")
    report = plan_profile(profile_arch(arch, shape),
                          HardwareSpec(ranks=2, memory_bytes=2.0 * 2**30),
                          shape_name=shape.name, microbatches=[2, 4])
    again = PlanReport.from_json(report.to_json())
    assert again.to_dict() == report.to_dict()
    assert again.best.spec == report.best.spec


def test_planner_executor_restriction():
    arch = configs.smoke_arch("smollm-360m")
    shape = ShapeConfig("smoke", 64, 8, "train")
    profile = profile_arch(arch, shape)
    hw = HardwareSpec(ranks=2, memory_bytes=2.0 * 2**30)
    report = plan_profile(profile, hw, shape_name=shape.name,
                          executors=("spmd",))
    assert report.candidates
    assert all(c.spec.schedule.executor == "spmd"
               for c in report.candidates)
    pcfg = ParallelConfig.auto(arch, shape, hw, executors=("spmd",))
    assert pcfg.executor == "spmd"


# ---------------------------------------------------------------------------
# Tripwire (b): BENCH dominance (planner top <= every hand-picked row)
# ---------------------------------------------------------------------------

@given(st.integers(0, 37))
@settings(max_examples=38, deadline=None)
def test_planner_dominates_bench_rows(idx):
    with open(BENCH) as f:
        rows = json.load(f)["rows"]
    row = rows[idx % len(rows)]
    batch = 16
    if batch % int(row["n_micro"]):
        return
    if row["model"] == "lm":
        profile = profile_arch(configs.smoke_arch("smollm-360m"),
                               ShapeConfig("smoke", 128, batch, "train"))
    else:
        from repro.models.unet import UNetConfig
        profile = profile_unet(UNetConfig(B=1, C=4, levels=3, img=32), batch)
    hw = HardwareSpec(ranks=int(row["pipe"]), memory_bytes=64.0 * 2**30)
    report = plan_profile(profile, hw, shape_name="bench")
    hand = score_candidate(profile, hw, _row_spec(row))
    top = report.best
    assert top is not None
    assert top.step_s <= hand.step_s * (1 + 1e-9), \
        (row["schedule"], row["n_micro"], top.step_s, hand.step_s)


# ---------------------------------------------------------------------------
# Acceptance: plan #1 trains bitwise-identically to the hand-built config
# ---------------------------------------------------------------------------

def test_auto_plan_trains_bitwise_like_hand_config():
    from conftest import run_subprocess
    run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.compat import set_mesh
        from repro import configs
        from repro.configs.base import ParallelConfig, PlanSpec, ShapeConfig
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.launch import mesh as mesh_lib, steps
        from repro.models.lm import LMModel
        from repro.optim import optimizers as optim
        from repro.planner import HardwareSpec, plan_arch

        arch = configs.smoke_arch("smollm-360m")
        shape = ShapeConfig("smoke", 32, 8, "train")
        hw = HardwareSpec(ranks=2, memory_bytes=2.0 * 2**30)
        report = plan_arch(arch, shape, hw)
        best = report.best.spec
        # round-trip through the JSON report, exactly like dryrun --plan
        best = PlanSpec.from_dict(
            type(report).from_json(report.to_json()).best.spec.to_dict())
        base = ParallelConfig(pipe=hw.ranks, tp=1, data=1, pod=1, n_micro=1)
        pcfg_auto = best.apply_to(base)
        pcfg_hand = base.with_(
            pipe=best.pipe, n_micro=best.microbatches,
            schedule=best.schedule.name,
            residuals=best.schedule.residuals,
            executor=best.schedule.executor, partition=best.partition)
        assert pcfg_auto == pcfg_hand

        def losses(pcfg):
            mesh = mesh_lib.make_smoke_mesh(pcfg)
            model = LMModel(arch, pcfg, dtype=jnp.float32)
            params = model.init(jax.random.PRNGKey(0))
            ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=4)
            opt = optim.init(ocfg, params)
            data = SyntheticLM(DataConfig(vocab=arch.vocab, seq_len=32,
                                          global_batch=8))
            out = []
            with set_mesh(mesh):
                step = jax.jit(steps.build_train_step(model, pcfg, mesh,
                                                      shape, ocfg))
                for i in range(3):
                    batch = {k: jnp.asarray(v)
                             for k, v in data.batch_at(i).items()}
                    params, opt, m = step(params, opt, batch)
                    out.append(float(m["loss"]))
            return out

        la, lh = losses(pcfg_auto), losses(pcfg_hand)
        assert la == lh, (la, lh)
        print("bitwise ok", la)
    """, n_devices=2, timeout=560)


def test_balanced_partition_trains_close_to_uniform():
    from conftest import run_subprocess
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro import configs
        from repro.configs.base import ParallelConfig, ShapeConfig
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.launch import mesh as mesh_lib, steps
        from repro.models.lm import LMModel
        from repro.optim import optimizers as optim

        arch = configs.smoke_arch("smollm-360m")   # 4 layers
        shape = ShapeConfig("smoke", 32, 8, "train")
        base = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=2,
                              schedule="1f1b")
        data = SyntheticLM(DataConfig(vocab=arch.vocab, seq_len=32,
                                      global_batch=8))

        def loss_of(pcfg):
            mesh = mesh_lib.make_smoke_mesh(pcfg)
            model = LMModel(arch, pcfg, dtype=jnp.float32)
            params = model.init(jax.random.PRNGKey(0))
            opt = optim.init(optim.OptimizerConfig(), params)
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
            with set_mesh(mesh):
                step = jax.jit(steps.build_train_step(
                    model, pcfg, mesh, shape))
                _, _, m = step(params, opt, batch)
            return float(m["loss"])

        l_uniform = loss_of(base)
        l_cut = loss_of(base.with_(partition=(3, 1)))
        # same math, different stage cuts: layer params are drawn from the
        # same per-layer keys, so losses agree to float tolerance
        assert np.isclose(l_uniform, l_cut, rtol=1e-5), (l_uniform, l_cut)
        print("partition ok", l_uniform, l_cut)
    """, n_devices=2, timeout=560)
