"""torchgpipe.balance analogue: block partition properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import balance as B

costs_s = st.lists(st.floats(0.01, 100.0), min_size=1, max_size=64)


@given(costs_s, st.integers(1, 16))
@settings(max_examples=100, deadline=None)
def test_partition_contiguous_complete(costs, n):
    sizes = B.block_partition(costs, n)
    assert len(sizes) == n
    assert sum(sizes) == len(costs)
    assert all(s >= 0 for s in sizes)
    if len(costs) >= n:
        assert all(s >= 1 for s in sizes)


@given(costs_s, st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_partition_minimax_bound(costs, n):
    """max block <= sum/n + max element (greedy bound) and is optimal vs
    brute force on small instances."""
    sizes = B.block_partition(costs, n)
    got = B.max_block_cost(costs, sizes)
    assert got <= sum(costs) / n + max(costs) + 1e-9


@given(st.lists(st.floats(0.01, 50.0), min_size=2, max_size=10),
       st.integers(2, 4))
@settings(max_examples=60, deadline=None)
def test_partition_optimal_small(costs, n):
    """Exhaustive check: no contiguous n-partition beats ours."""
    if len(costs) < n:
        return
    sizes = B.block_partition(costs, n)
    got = B.max_block_cost(costs, sizes)

    import itertools
    best = float("inf")
    L = len(costs)
    for cuts in itertools.combinations(range(1, L), n - 1):
        bounds = [0, *cuts, L]
        m = max(sum(costs[bounds[i]:bounds[i + 1]]) for i in range(n))
        best = min(best, m)
    assert got <= best * (1 + 1e-9) + 1e-9


def test_balance_by_size():
    sizes = B.balance_by_size([10, 10, 10, 10], 2)
    assert sizes == [2, 2]
    sizes = B.balance_by_size([30, 10, 10, 10], 2)
    assert sizes == [1, 3]


def test_balance_by_flops_profiles_compiled_layers():
    """The construct-and-run analogue of torchgpipe's profiling pass."""
    import jax
    import jax.numpy as jnp
    big = lambda x: x @ jnp.ones((64, 64)) @ jnp.ones((64, 64))
    small = lambda x: x @ jnp.ones((64, 64))
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    sizes = B.balance_by_flops([big, small, small], [x, x, x], 2)
    assert sizes == [1, 2]  # big layer alone; two small layers together


def test_fewer_layers_than_stages():
    sizes = B.block_partition([1.0, 1.0], 4)
    assert sizes == [1, 1, 0, 0]
