"""Schedule-driven execution: the fused scheduler (repro.core.plan +
run_pipeline_tasks) must make 1F1B, GPipe, interleaved and split-backward
*the same computation in a different order* — bitwise-identical losses and
gradients — and must match the legacy autodiff backward to numerical
tolerance.

Host-side plan properties run in-process; executor equivalence runs on 8
XLA host devices in a subprocess (one subprocess amortizes jit time over
the whole (pipe, m) grid)."""
import numpy as np
import pytest

from conftest import run_subprocess

from repro.core import plan as PL
from repro.core import schedules as S


# ---------------------------------------------------------------------------
# Plan lowering properties (host-side, no devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 1), (4, 1), (1, 4), (4, 2), (8, 2),
                                 (4, 4), (8, 4), (6, 3)])
def test_plan_stash_bound_and_donated_park(m, n):
    """``per_stage_stash`` carries the schedule-level bound (peak_stash:
    ``m`` for GPipe, ``min(n - j, m)`` for 1F1B); ``per_stage_park`` is the
    DONATED arrival-buffer high-water the executor actually allocates —
    non-uniform, with stage 0 parking nothing (its input is re-gathered,
    not stashed), and never above bound + the one-tick in-flight arrival."""
    for name, table in (("gpipe", S.gpipe_schedule(m, n, checkpoint=False)),
                        ("1f1b", S.one_f_one_b_schedule(m, n))):
        plan = PL.lower_tasks(table, m, n)
        assert list(plan.per_stage_stash) == S.peak_stash(table, n), name
        assert plan.park_depth == max(plan.per_stage_park)
        assert plan.per_stage_park[0] == 0     # stage 0: nothing to park
        for j in range(n):
            assert plan.per_stage_park[j] <= plan.per_stage_stash[j] + 1
    gpipe = PL.plan_for("gpipe", m, n)
    f1b = PL.plan_for("1f1b", m, n)
    assert all(gpipe.per_stage_stash[j] == m for j in range(n))
    # the true per-stage bound, not a flattened SPMD max: stage j stashes
    # at most min(n - j, m) micro-batches under 1F1B
    assert all(f1b.per_stage_stash[j] == min(n - j, m) for j in range(n))
    assert (f1b.per_stage_stash_bytes(100)
            == tuple(100 * d for d in f1b.per_stage_park))
    # 1F1B's memory bound is the point: strictly better whenever m > n
    if m > n and n > 1:
        assert f1b.park_depth < gpipe.park_depth


@pytest.mark.parametrize("schedule,v", [("gpipe", 1), ("1f1b", 1),
                                        ("zb", 1), ("interleaved:2", 2)])
@pytest.mark.parametrize("m,n", [(4, 2), (8, 4), (4, 4)])
def test_plan_task_coverage(schedule, v, m, n):
    """Every task appears exactly once, at most one task per rank per tick,
    park/backward-inbox arrivals never overtake their consumers, and every
    parked slot is consumed."""
    p = PL.plan_for(schedule, m, n)
    assert p.n_ranks == n and p.n_chunks == v and p.n_stages == n * v
    split = schedule == "zb"
    seen = set()
    for t in range(p.n_ticks):
        for j in range(n):
            k = p.kind[t, j]
            if k == PL.NOP:
                continue
            s = int(p.chunk[t, j]) * n + j
            task = (int(k), int(p.micro[t, j]), s)
            assert task not in seen, task
            seen.add(task)
            if s > 0 and k == PL.FWD:
                assert p.park_read[t, j] >= 0   # boundary input is parked
    per_stage_kinds = 3 if split else 2
    assert len(seen) == per_stage_kinds * m * n * v, schedule
    # slot pairing: a parked value is read at least once before its slot is
    # overwritten, and nothing stays parked forever
    for arr, rd in ((p.park_recv, p.park_read), (p.b_recv, p.b_read)):
        for j in range(n):
            events = []
            for t in range(p.n_ticks):
                if arr[t, j] >= 0:
                    events.append(("park", t, int(arr[t, j])))
                if rd[t, j] >= 0:
                    events.append(("read", t, int(rd[t, j])))
            read_since_park = {}
            # sort by (tick, event): "park" < "read", so a same-tick
            # arrive-then-consume pairs up correctly
            for ev, t, slot in sorted(events, key=lambda e: (e[1], e[0])):
                if ev == "park":
                    assert read_since_park.get(slot, True), \
                        f"slot {slot} overwritten unread at tick {t}"
                    read_since_park[slot] = False
                elif slot in read_since_park:
                    read_since_park[slot] = True
            assert all(read_since_park.values()), \
                f"rank {j}: parked value never consumed"


def test_plan_zb_split_events():
    """Split-backward lowering: Bw re-reads the SAME park / b-inbox slots
    its Bx used (the weight grad re-seeds from the parked cotangent), and
    ticks where a rank would idle under 1F1B now carry Bw work."""
    m, n = 8, 4
    p = PL.plan_for("zb", m, n)
    f1b = PL.plan_for("1f1b", m, n)
    kinds = set(int(k) for k in p.kind.ravel())
    assert PL.BWD_X in kinds and PL.BWD_W in kinds and PL.BWD not in kinds
    # every (micro, stage) Bx/Bw pair shares its park slot
    for j in range(n):
        by_micro = {}
        for t in range(p.n_ticks):
            if p.kind[t, j] in (PL.BWD_X, PL.BWD_W):
                by_micro.setdefault(int(p.micro[t, j]), []).append(
                    (int(p.kind[t, j]), int(p.park_read[t, j]),
                     int(p.b_read[t, j])))
        for i, evs in by_micro.items():
            assert len(evs) == 2, (j, i)
            (kx, px, bx), (kw, pw, bw) = sorted(evs)
            assert (kx, kw) == (PL.BWD_X, PL.BWD_W)
            assert px == pw and bx == bw, (j, i)
    # the fill: zb has strictly fewer idle slots than 1f1b
    assert (p.kind == PL.NOP).sum() / p.kind.size \
        < (f1b.kind == PL.NOP).sum() / f1b.kind.size


def test_plan_interleaved_chunks():
    """Interleaved lowering: rank r hosts chunks {r, r+n, ...}; the chunk
    column selects them; per-rank park covers both chunks' arrivals."""
    m, n, v = 8, 4, 2
    p = PL.plan_for("interleaved:2", m, n)
    assert p.n_chunks == v and p.n_stages == n * v
    for t in range(p.n_ticks):
        for j in range(n):
            if p.kind[t, j] != PL.NOP:
                assert 0 <= p.chunk[t, j] < v
    # every global stage s executes on rank s % n with chunk s // n
    stages_seen = set()
    for t in range(p.n_ticks):
        for j in range(n):
            if p.kind[t, j] == PL.FWD:
                stages_seen.add(int(p.chunk[t, j]) * n + j)
    assert stages_seen == set(range(n * v))
    table = S.interleaved_1f1b_schedule(m, n, v)
    assert list(p.per_stage_stash) == S.peak_stash(table, n * v, ranks=n)


def test_plan_segments_and_compaction():
    """Segments partition the tick axis, each declaring exactly the branch
    set its ticks use; all-rank-NOP ticks are dropped at lowering."""
    for schedule, m, n in [("gpipe_tasked", 8, 4), ("1f1b", 8, 4),
                           ("zb", 8, 4), ("interleaved:2", 8, 4)]:
        p = PL.plan_for(schedule, m, n)
        assert len(p.segments) <= PL.MAX_SEGMENTS
        assert p.segments[0].start == 0 and p.segments[-1].stop == p.n_ticks
        for a, b in zip(p.segments, p.segments[1:]):
            assert a.stop == b.start
        for seg in p.segments:
            used = set(int(k) for k in p.kind[seg.start:seg.stop].ravel())
            assert used <= set(seg.kinds), (schedule, seg)
        # no tick is empty (compaction) — some rank works every tick
        assert ((p.kind != PL.NOP).sum(axis=1) > 0).all(), schedule
    # GPipe's fill is a pure-F phase: its first segment has no B branches
    g = PL.plan_for("gpipe_tasked", 8, 4)
    assert not (set(g.segments[0].kinds)
                & {PL.BWD, PL.BWD_X, PL.BWD_W})


def test_forward_plan_is_clock_cycle():
    """The forward-only plan reproduces Algorithm 1's F_{t-j, j}
    arithmetic: the same executor that runs fused F+B tables runs this
    plan for inference / autodiff-backward execution."""
    m, n = 6, 4
    p = PL.plan_for("gpipe_fwd", m, n)
    assert not p.has_backward
    assert p.n_ticks == m + n - 1
    for t in range(p.n_ticks):
        for j in range(n):
            if 0 <= t - j < m:
                assert p.kind[t, j] == PL.FWD and p.micro[t, j] == t - j
            else:
                assert p.kind[t, j] == PL.NOP
    # no backward machinery in a forward-only plan
    assert (p.b_read == -1).all() and (p.b_recv == -1).all()


def test_device_model_schedule_payoff():
    """The dedicated-device critical path (the schedule-comparison clock)
    shows the new schedules' payoff: interleaving strictly undercuts 1F1B
    at every grid point; split backward wins exactly where the 1F1B bubble
    outweighs its extra recompute (m close to n)."""
    cases = [(4, 4), (8, 4), (8, 2)]
    for m, n in cases:
        t_f, _ = S.simulate_device_times(S.one_f_one_b_schedule(m, n), n)
        t_g, _ = S.simulate_device_times(
            S.gpipe_schedule(m, n, checkpoint=False), n)
        assert t_f == pytest.approx(t_g)   # same critical path (flush)
        t_i, _ = S.simulate_device_times(
            S.interleaved_1f1b_schedule(m, n, 2),
            n, S.default_task_cost(2 * n, n))
        assert t_i < t_f, (m, n)
    t_zb, _ = S.simulate_device_times(S.zb_schedule(4, 4), 4)
    t_f, _ = S.simulate_device_times(S.one_f_one_b_schedule(4, 4), 4)
    assert t_zb < t_f                      # high-bubble regime: zb pays off


# ---------------------------------------------------------------------------
# Executor equivalence (8 host devices, subprocess)
# ---------------------------------------------------------------------------

EXEC_GRID = """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core.pipeline import (pipeline_call, pipeline_grad_call,
                                 microbatch, last_stage_output, unmicrobatch)

arch = configs.smoke_arch("smollm-360m")
key = jax.random.PRNGKey(0)

def loss_and_grads(schedule, pipe, m, data):
    shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=data, pod=1, n_micro=m,
                          remat="full", schedule=schedule)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {k: jax.random.randint(jax.random.fold_in(key, len(k)),
                                   v.shape, 0, arch.vocab)
             for k, v in model.input_specs(shape).items()}
    consts = model.consts()
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        if schedule == "gpipe":      # legacy autodiff path (reference)
            pipe_fn = pipeline_call(model.make_stage_apply(consts),
                                    mesh=mesh, cfg=pcfg, carry_proto=cp)
            def loss_fn(p, b):
                fresh = model.embed_inputs(p["embed"], b)
                outs, _ = pipe_fn(p["stages"], microbatch(fresh, m), None)
                h = unmicrobatch(last_stage_output(outs)["h"])
                return model.head_loss(p, h, b["labels"])
            loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
            return np.asarray(loss), jax.tree.map(np.asarray, grads)
        pg, tplan = pipeline_grad_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            carry_proto=cp)
        # structural memory bound: the park buffer depth is decided by the
        # plan, before any tracing
        expect = ([min(pipe - j, m) for j in range(pipe)]
                  if schedule == "1f1b" else [m] * pipe)
        assert list(tplan.per_stage_stash) == expect, tplan.per_stage_stash
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
        return np.asarray(loss), jax.tree.map(np.asarray, grads)

for pipe, m, data in [(1, 4, 1), (2, 4, 1), (2, 8, 2), (4, 4, 1), (4, 8, 2)]:
    l_t, g_t = loss_and_grads("gpipe_tasked", pipe, m, data)
    l_f, g_f = loss_and_grads("1f1b", pipe, m, data)
    # 1F1B vs GPipe through the fused scheduler: bitwise identical
    assert np.array_equal(l_t, l_f), (pipe, m, data, l_t, l_f)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_t)[0],
                            jax.tree_util.tree_leaves(g_f)):
        assert np.array_equal(a, b), (pipe, m, data, path)
    # fused gpipe vs legacy autodiff gpipe: same math, different graph
    l_r, g_r = loss_and_grads("gpipe", pipe, m, data)
    np.testing.assert_allclose(l_t, l_r, rtol=2e-5)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_r)[0],
                            jax.tree_util.tree_leaves(g_t)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                                   err_msg=f"{(pipe, m, data)} {path}")
    print("grid point OK", pipe, m, data)
print("SCHEDULE EXEC EQUIV OK")
"""


def test_1f1b_equals_gpipe_bitwise_and_legacy_close():
    out = run_subprocess(EXEC_GRID, n_devices=8, timeout=1800)
    assert "SCHEDULE EXEC EQUIV OK" in out


MPMD_BITWISE = """
import zlib
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.models import pipeline_hetero as PH
from repro.models.unet import UNetConfig, UNetModel
from repro.core.pipeline import pipeline_grad_call, microbatch, unmicrobatch

key = jax.random.PRNGKey(0)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")

def lm_lg(arch_name, schedule, pipe, m, executor, residuals="recompute",
          remat="full", stream=False, data=1):
    arch = configs.smoke_arch(arch_name)
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=data, pod=1, n_micro=m,
                          remat=remat, schedule=schedule,
                          residuals=residuals, executor=executor,
                          stream_inputs=stream)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {}
    for k, v in model.input_specs(shape).items():
        kk = jax.random.fold_in(key, zlib.crc32(k.encode()) % 1000)
        batch[k] = (jax.random.randint(kk, v.shape, 0, arch.vocab)
                    if v.dtype == jnp.int32
                    else jax.random.normal(kk, v.shape, v.dtype) * 0.1)
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        pg, _ = pipeline_grad_call(
            model.make_stage_apply(model.consts()), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            skips=model.skips(), skip_protos=model.skip_protos(mbg, 16),
            carry_proto=cp)
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
    return np.asarray(loss), jax.tree.map(np.asarray, grads)

def check(tag, a, b):
    la, ga = a
    lb, gb = b
    assert np.array_equal(la, lb), (tag, la, lb)
    for (path, x), y in zip(jax.tree_util.tree_flatten_with_path(ga)[0],
                            jax.tree_util.tree_leaves(gb)):
        assert np.array_equal(x, y), (tag, path)
    print("MPMD BITWISE OK", *tag)

# LM: every fused schedule family, plus streaming, plus pipe=4 with DP
for case in [("1f1b", 2, 4, "recompute", "full", False, 1),
             ("gpipe_tasked", 2, 4, "recompute", "full", False, 1),
             ("interleaved:2", 2, 4, "recompute", "full", False, 1),
             ("zb", 2, 4, "recompute", "full", False, 1),
             ("zb", 2, 4, "reuse", "dots", False, 1),
             ("1f1b", 2, 4, "recompute", "full", True, 1),
             ("1f1b", 4, 8, "recompute", "full", False, 2)]:
    sched, pipe, m, residuals, remat, stream, data = case
    spmd = lm_lg("smollm-360m", sched, pipe, m, "spmd", residuals, remat,
                 stream, data)
    mpmd = lm_lg("smollm-360m", sched, pipe, m, "mpmd", residuals, remat,
                 stream, data)
    check(("lm",) + case, spmd, mpmd)

# whisper encoder-decoder: multi-destination skip portals through the plan
for sched, residuals, remat in [("1f1b", "recompute", "full"),
                                ("zb", "reuse", "dots")]:
    spmd = lm_lg("whisper-tiny", sched, 2, 4, "spmd", residuals, remat)
    mpmd = lm_lg("whisper-tiny", sched, 2, 4, "mpmd", residuals, remat)
    check(("whisper", sched, residuals), spmd, mpmd)

# U-Net heterogeneous (switch-program) portals
ucfg = UNetConfig(B=1, C=8, levels=3, img=16)
UB, pipe, m = 8, 2, 4
x = jax.random.normal(jax.random.fold_in(key, 7), (UB, ucfg.img, ucfg.img, 3))
results = {}
for executor in ("spmd", "mpmd"):
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                          portals=True, schedule="1f1b", executor=executor)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    umodel = UNetModel(ucfg, pipe)
    uparams = umodel.init(jax.random.PRNGKey(0))
    prog = PH.build_hetero_program(umodel, uparams, UB // m, pcfg, x[:2])
    tgt = jnp.zeros((UB,) + tuple(prog.out_proto.shape[1:]), jnp.float32)
    with set_mesh(mesh):
        call = jax.jit(PH.hetero_grad_call(prog, mesh, pcfg))
        loss, g_stage = call(prog.stacked_params, x, tgt)
    results[executor] = (np.asarray(loss), np.asarray(g_stage))
assert np.array_equal(results["spmd"][0], results["mpmd"][0])
assert np.array_equal(results["spmd"][1], results["mpmd"][1])
print("MPMD BITWISE OK unet-hetero")
print("ALL MPMD BITWISE OK")
"""


def test_mpmd_executor_bitwise_vs_spmd():
    """The MPMD lowering (per-rank specialized programs + double-buffered
    chain sends) is bitwise-identical in loss AND grads to the SPMD
    reference for every fused schedule family — on the LM, the whisper
    portal model and the hetero U-Net, including streamed inputs, DP, and
    residual reuse."""
    out = run_subprocess(MPMD_BITWISE, n_devices=8, timeout=2400)
    assert "ALL MPMD BITWISE OK" in out


TRAIN_1F1B = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
for schedule in ("1f1b", "zb", "interleaved:2"):
    pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=4,
                          schedule=schedule)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
    params = model.init(jax.random.PRNGKey(0))
    ocfg = optim.OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)
    opt = optim.init(ocfg, params)
    with set_mesh(mesh):
        step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape,
                                              ocfg))
        batch = {k: jax.random.randint(jax.random.PRNGKey(1), v.shape, 0,
                                       arch.vocab)
                 for k, v in model.input_specs(shape).items()}
        losses = []
        for _ in range(6):
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), (schedule, losses)
    assert losses[-1] < losses[0] * 0.9, (schedule, losses)
    print("TRAIN OK", schedule, losses[0], "->", losses[-1])
print("ALL TRAIN OK")
"""


def test_fused_train_loops_converge():
    """End-to-end: schedule="1f1b" / "zb" / "interleaved:2" through
    build_train_step memorize a fixed batch on an 8-device mesh
    (pipeline + DP + AdamW)."""
    out = run_subprocess(TRAIN_1F1B, n_devices=8, timeout=1500)
    assert "ALL TRAIN OK" in out


UNIFIED_EXTRAS = """
import zlib
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat, configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core import plan as plan_lib
from repro.core.pipeline import (pipeline_call, pipeline_grad_call,
                                 run_pipeline_tasks, microbatch,
                                 last_stage_output, unmicrobatch)

key = jax.random.PRNGKey(0)

# --- 1. skip-connection model: all fused schedules vs legacy GPipe -------
arch = configs.smoke_arch("whisper-tiny")
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")

def whisper_lg(schedule, pipe, m, stream=False):
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                          remat="full", schedule=schedule,
                          stream_inputs=stream)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {}
    for k, v in model.input_specs(shape).items():
        kk = jax.random.fold_in(key, zlib.crc32(k.encode()) % 1000)
        batch[k] = (jax.random.randint(kk, v.shape, 0, arch.vocab)
                    if v.dtype == jnp.int32
                    else jax.random.normal(kk, v.shape, v.dtype) * 0.1)
    consts = model.consts()
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        if schedule == "gpipe":       # legacy semantics: autodiff backward
            pipe_fn = pipeline_call(
                model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
                skips=model.skips(),
                skip_protos=model.skip_protos(mbg, 16), carry_proto=cp)
            def loss_fn(p, b):
                fresh = model.embed_inputs(p["embed"], b)
                outs, _ = pipe_fn(p["stages"], microbatch(fresh, m), None)
                h = unmicrobatch(last_stage_output(outs)["h"])
                return model.head_loss(p, h, b["labels"])
            loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
            return np.asarray(loss), jax.tree.map(np.asarray, grads)
        pg, tplan = pipeline_grad_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            skips=model.skips(), skip_protos=model.skip_protos(mbg, 16),
            carry_proto=cp)
        # portal events made it into the plan
        assert {rt.name for rt in tplan.routes} \
            == {s.name for s in model.skips()}
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
        return np.asarray(loss), jax.tree.map(np.asarray, grads)

def assert_bitwise(ga, gb, tag):
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(ga)[0],
                            jax.tree_util.tree_leaves(gb)):
        assert np.array_equal(a, b), (tag, path)

for pipe, m in [(2, 4), (4, 4)]:
    l_t, g_t = whisper_lg("gpipe_tasked", pipe, m)
    l_f, g_f = whisper_lg("1f1b", pipe, m)
    l_z, g_z = whisper_lg("zb", pipe, m)
    assert np.array_equal(l_t, l_f), (pipe, m, l_t, l_f)
    assert np.array_equal(l_t, l_z), (pipe, m, l_t, l_z)
    assert_bitwise(g_t, g_f, ("1f1b", pipe, m))
    # split backward through skip portals: Bx ships the skip cotangents on
    # the critical path, Bw re-seeds the weight VJP — still bitwise
    assert_bitwise(g_t, g_z, ("zb", pipe, m))
    l_r, g_r = whisper_lg("gpipe", pipe, m)
    np.testing.assert_allclose(l_t, l_r, rtol=2e-5)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_r)[0],
                            jax.tree_util.tree_leaves(g_t)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                                   err_msg=f"{(pipe, m)} {path}")
    print("skip-model grid point OK", pipe, m)

# --- 1b. interleaved: same GLOBAL stage split on half the ranks is the
# SAME computation bitwise: interleaved:2 @ pipe=2 == 1f1b @ pipe=4
# (both cut whisper into 4 global stages; the portal whose src and dst
# land on one rank becomes an identity hold).
l4, g4 = whisper_lg("1f1b", 4, 8)
li, gi = whisper_lg("interleaved:2", 2, 8)
assert np.array_equal(l4, li), (l4, li)
assert_bitwise(g4, gi, "interleaved-vs-1f1b")
print("interleaved bitwise OK")

# --- 2. streamed inputs through the fused executor: bitwise --------------
l0, g0 = whisper_lg("1f1b", 4, 8, stream=False)
l1, g1 = whisper_lg("1f1b", 4, 8, stream=True)
assert np.array_equal(l0, l1), (l0, l1)
assert_bitwise(g0, g1, "streamed-1f1b")
lz1, gz1 = whisper_lg("zb", 4, 8, stream=True)
lz0, gz0 = whisper_lg("zb", 4, 8, stream=False)
assert np.array_equal(lz0, lz1)
assert_bitwise(gz0, gz1, "streamed-zb")
print("streamed fused OK")

# --- 3. resident state threaded through an F+B step ----------------------
n, m, mb, D = 2, 4, 2, 8
pcfg = ParallelConfig(pipe=n, tp=1, data=1, pod=1, n_micro=m,
                      schedule="1f1b", remat="full")
mesh = mesh_lib.make_smoke_mesh(pcfg)
W = jax.random.normal(key, (n, D, D)) * 0.3
x = jax.random.normal(jax.random.fold_in(key, 1), (m, mb, D))
labels = jax.random.normal(jax.random.fold_in(key, 2), (m, mb, D))

def stage_apply(p, carry, skips_in, resident, ctx):
    h = jnp.where(ctx.stage == 0, ctx.fresh["h"], carry["h"])
    h2 = jnp.tanh(h @ p)
    res = dict(resident)
    if "seen" in res:
        res["seen"] = jax.lax.dynamic_update_index_in_dim(
            resident["seen"], jnp.mean(h2), ctx.micro, 0)
    return {"h": h2}, {}, res

def loss_fn(hp, carry, la):
    return jnp.mean((carry["h"] - la["y"]) ** 2)

tplan = plan_lib.plan_for("1f1b", m, n)

def run(with_res):
    resident = {"seen": jnp.zeros((m,))} if with_res else {}
    def inner(rank, res):
        with compat.manual_region():
            loss, gs, gh, ig, res2 = run_pipeline_tasks(
                stage_apply, W[rank[0]], {"h": x}, pcfg, tplan=tplan,
                head_params={}, loss_args_mb={"y": labels},
                loss_fn=loss_fn, resident=jax.tree.map(lambda a: a[0], res),
                rank=rank[0])
            return (loss[None], jax.tree.map(lambda a: a[None], gs),
                    jax.tree.map(lambda a: a[None], res2))
    fn = compat.shard_map(inner, mesh=mesh, in_specs=(P("pipe"), P("pipe")),
                          out_specs=(P("pipe"), P("pipe"), P("pipe")),
                          axis_names={"pipe"}, check_vma=False)
    rk = jnp.arange(n, dtype=jnp.int32)
    rr = jax.tree.map(lambda a: jnp.stack([a] * n), resident)
    return jax.jit(lambda: fn(rk, rr))()

loss0, g0, _ = run(False)
loss1, g1, res = run(True)
# resident must not perturb the training computation ...
assert np.array_equal(np.asarray(loss0), np.asarray(loss1))
assert np.array_equal(np.asarray(g0), np.asarray(g1))
# ... and must hold each stage's per-micro statistics, updated on F ticks
h = x
expect = []
for j in range(n):
    h = jnp.tanh(h @ W[j])
    expect.append(jnp.mean(h, axis=(1, 2)))
np.testing.assert_allclose(np.asarray(res["seen"]), np.stack(expect),
                           rtol=1e-6)
print("resident fused OK")
print("UNIFIED EXTRAS OK")
"""


def test_unified_executor_skips_streaming_resident():
    """The tentpole's acceptance surface: (1) a skip-connection model runs
    ALL fused F+B schedules (gpipe_tasked / 1f1b / zb) with
    bitwise-identical losses and grads, matching the autodiff reference to
    tolerance; (2) interleaved:2 on half the ranks is bitwise-identical to
    1f1b on the full rank count (same global stage split — the same
    computation, reordered); (3) ``stream_inputs`` lowers to plan injection
    ticks and is bitwise vs replicated inputs for both fused and
    split-backward schedules; (4) resident state threads through an F+B
    step without perturbing gradients."""
    out = run_subprocess(UNIFIED_EXTRAS, n_devices=8, timeout=2400)
    assert "UNIFIED EXTRAS OK" in out
