"""Schedule-driven execution: the fused scheduler (repro.core.plan +
run_pipeline_tasks) must make 1F1B and GPipe *the same computation in a
different order* — bitwise-identical losses and gradients — and must match
the legacy autodiff backward to numerical tolerance.

Host-side plan properties run in-process; executor equivalence runs on 8
XLA host devices in a subprocess (one subprocess amortizes jit time over
the whole (pipe, m) grid)."""
import pytest

from conftest import run_subprocess

from repro.core import plan as PL
from repro.core import schedules as S


# ---------------------------------------------------------------------------
# Plan lowering properties (host-side, no devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 1), (4, 1), (1, 4), (4, 2), (8, 2),
                                 (4, 4), (8, 4), (6, 3)])
def test_plan_stash_matches_peak_stash(m, n):
    """The executor's stash buffer is sized by the plan; the plan's
    per-stage high-water mark must equal schedules.peak_stash exactly."""
    for name, table in (("gpipe", S.gpipe_schedule(m, n, checkpoint=False)),
                        ("1f1b", S.one_f_one_b_schedule(m, n))):
        plan = PL.lower_tasks(table, m, n)
        assert list(plan.per_stage_stash) == S.peak_stash(table, n, m), name
        assert plan.stash_depth == max(plan.per_stage_stash)
    gpipe = PL.plan_for("gpipe", m, n)
    f1b = PL.plan_for("1f1b", m, n)
    assert all(gpipe.per_stage_stash[j] == m for j in range(n))
    # the true per-stage depth, not the flattened SPMD max (satellite):
    # stage j stashes exactly min(n - j, m) micro-batches under 1F1B
    assert all(f1b.per_stage_stash[j] == min(n - j, m) for j in range(n))
    assert (f1b.per_stage_stash_bytes(100)
            == tuple(100 * min(n - j, m) for j in range(n)))
    # 1F1B's memory bound is the point: strictly better whenever m > n
    if m > n:
        assert f1b.stash_depth < gpipe.stash_depth


@pytest.mark.parametrize("m,n", [(4, 2), (8, 4), (5, 3)])
def test_plan_task_coverage(m, n):
    """Every F and B task appears exactly once, at most one task per rank
    per tick, and ring arrivals never overtake their consumers."""
    for name in ("gpipe", "1f1b"):
        p = PL.plan_for(name, m, n)
        seen = set()
        for t in range(p.n_ticks):
            for j in range(n):
                k = p.kind[t, j]
                if k == PL.NOP:
                    continue
                task = ("F" if k == PL.FWD else "B", int(p.micro[t, j]), j)
                assert task not in seen, task
                seen.add(task)
                assert p.stash_slot[t, j] >= 0
        assert len(seen) == 2 * m * n, name
        # inbox slot pairing: each recv is read later (or same tick)
        for arr, rd in ((p.f_recv_slot, p.f_read_slot),
                        (p.b_recv_slot, p.b_read_slot)):
            for j in range(n):
                pending = {}
                for t in range(p.n_ticks):
                    if arr[t, j] >= 0:
                        assert arr[t, j] not in pending, "slot overwritten"
                        pending[int(arr[t, j])] = t
                    if rd[t, j] >= 0:
                        assert int(rd[t, j]) in pending, "read before arrival"
                        del pending[int(rd[t, j])]
                assert not pending, "arrival never consumed"


def test_forward_plan_is_clock_cycle():
    """The forward-only plan reproduces Algorithm 1's F_{t-j, j}
    arithmetic: the same executor that runs fused F+B tables runs this
    plan for inference / autodiff-backward execution."""
    m, n = 6, 4
    p = PL.plan_for("gpipe_fwd", m, n)
    assert not p.has_backward
    assert p.n_ticks == m + n - 1
    for t in range(p.n_ticks):
        for j in range(n):
            if 0 <= t - j < m:
                assert p.kind[t, j] == PL.FWD and p.micro[t, j] == t - j
            else:
                assert p.kind[t, j] == PL.NOP
    # no backward machinery in a forward-only plan
    assert (p.stash_slot == -1).all() and (p.b_read_slot == -1).all()


# ---------------------------------------------------------------------------
# Executor equivalence (8 host devices, subprocess)
# ---------------------------------------------------------------------------

EXEC_GRID = """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core.pipeline import (pipeline_call, pipeline_grad_call,
                                 microbatch, last_stage_output, unmicrobatch)

arch = configs.smoke_arch("smollm-360m")
key = jax.random.PRNGKey(0)

def loss_and_grads(schedule, pipe, m, data):
    shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=data, pod=1, n_micro=m,
                          remat="full", schedule=schedule)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {k: jax.random.randint(jax.random.fold_in(key, len(k)),
                                   v.shape, 0, arch.vocab)
             for k, v in model.input_specs(shape).items()}
    consts = model.consts()
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        if schedule == "gpipe":      # legacy autodiff path (reference)
            pipe_fn = pipeline_call(model.make_stage_apply(consts),
                                    mesh=mesh, cfg=pcfg, carry_proto=cp)
            def loss_fn(p, b):
                fresh = model.embed_inputs(p["embed"], b)
                outs, _ = pipe_fn(p["stages"], microbatch(fresh, m), None)
                h = unmicrobatch(last_stage_output(outs)["h"])
                return model.head_loss(p, h, b["labels"])
            loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
            return np.asarray(loss), jax.tree.map(np.asarray, grads)
        pg, tplan = pipeline_grad_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            carry_proto=cp)
        # structural memory bound: the stash buffer depth is decided by the
        # plan, before any tracing
        import repro.core.schedules as S
        expect = ([min(pipe - j, m) for j in range(pipe)]
                  if schedule == "1f1b" else [m] * pipe)
        assert list(tplan.per_stage_stash) == expect, tplan.per_stage_stash
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
        return np.asarray(loss), jax.tree.map(np.asarray, grads)

for pipe, m, data in [(1, 4, 1), (2, 4, 1), (2, 8, 2), (4, 4, 1), (4, 8, 2)]:
    l_t, g_t = loss_and_grads("gpipe_tasked", pipe, m, data)
    l_f, g_f = loss_and_grads("1f1b", pipe, m, data)
    # 1F1B vs GPipe through the fused scheduler: bitwise identical
    assert np.array_equal(l_t, l_f), (pipe, m, data, l_t, l_f)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_t)[0],
                            jax.tree_util.tree_leaves(g_f)):
        assert np.array_equal(a, b), (pipe, m, data, path)
    # fused gpipe vs legacy autodiff gpipe: same math, different graph
    l_r, g_r = loss_and_grads("gpipe", pipe, m, data)
    np.testing.assert_allclose(l_t, l_r, rtol=2e-5)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_r)[0],
                            jax.tree_util.tree_leaves(g_t)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                                   err_msg=f"{(pipe, m, data)} {path}")
    print("grid point OK", pipe, m, data)
print("SCHEDULE EXEC EQUIV OK")
"""


def test_1f1b_equals_gpipe_bitwise_and_legacy_close():
    out = run_subprocess(EXEC_GRID, n_devices=8, timeout=1800)
    assert "SCHEDULE EXEC EQUIV OK" in out


TRAIN_1F1B = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=4,
                      schedule="1f1b")
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = LMModel(arch, pcfg, dtype=jnp.float32)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
params = model.init(jax.random.PRNGKey(0))
ocfg = optim.OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)
opt = optim.init(ocfg, params)
with set_mesh(mesh):
    step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))
    batch = {k: jax.random.randint(jax.random.PRNGKey(1), v.shape, 0,
                                   arch.vocab)
             for k, v in model.input_specs(shape).items()}
    losses = []
    for _ in range(6):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0] * 0.9, losses
print("1F1B TRAIN OK", losses[0], "->", losses[-1])
"""


def test_1f1b_train_loop_converges():
    """End-to-end: schedule="1f1b" through build_train_step memorizes a
    fixed batch on an 8-device mesh (pipeline + DP + AdamW)."""
    out = run_subprocess(TRAIN_1F1B, n_devices=8, timeout=900)
    assert "1F1B TRAIN OK" in out


UNIFIED_EXTRAS = """
import zlib
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat, configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core import plan as plan_lib
from repro.core.pipeline import (pipeline_call, pipeline_grad_call,
                                 run_pipeline_tasks, microbatch,
                                 last_stage_output, unmicrobatch)

key = jax.random.PRNGKey(0)

# --- 1. skip-connection model: fused 1F1B == legacy-lowered GPipe --------
arch = configs.smoke_arch("whisper-tiny")
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")

def whisper_lg(schedule, pipe, m, stream=False):
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                          remat="full", schedule=schedule,
                          stream_inputs=stream)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {}
    for k, v in model.input_specs(shape).items():
        kk = jax.random.fold_in(key, zlib.crc32(k.encode()) % 1000)
        batch[k] = (jax.random.randint(kk, v.shape, 0, arch.vocab)
                    if v.dtype == jnp.int32
                    else jax.random.normal(kk, v.shape, v.dtype) * 0.1)
    consts = model.consts()
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        if schedule == "gpipe":       # legacy semantics: autodiff backward
            pipe_fn = pipeline_call(
                model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
                skips=model.skips(),
                skip_protos=model.skip_protos(mbg, 16), carry_proto=cp)
            def loss_fn(p, b):
                fresh = model.embed_inputs(p["embed"], b)
                outs, _ = pipe_fn(p["stages"], microbatch(fresh, m), None)
                h = unmicrobatch(last_stage_output(outs)["h"])
                return model.head_loss(p, h, b["labels"])
            loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
            return np.asarray(loss), jax.tree.map(np.asarray, grads)
        pg, tplan = pipeline_grad_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            skips=model.skips(), skip_protos=model.skip_protos(mbg, 16),
            carry_proto=cp)
        # portal events made it into the plan
        assert {rt.name for rt in tplan.routes} \
            == {s.name for s in model.skips()}
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
        return np.asarray(loss), jax.tree.map(np.asarray, grads)

for pipe, m in [(2, 4), (4, 4)]:
    l_t, g_t = whisper_lg("gpipe_tasked", pipe, m)
    l_f, g_f = whisper_lg("1f1b", pipe, m)
    assert np.array_equal(l_t, l_f), (pipe, m, l_t, l_f)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_t)[0],
                            jax.tree_util.tree_leaves(g_f)):
        assert np.array_equal(a, b), (pipe, m, path)
    l_r, g_r = whisper_lg("gpipe", pipe, m)
    np.testing.assert_allclose(l_t, l_r, rtol=2e-5)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_r)[0],
                            jax.tree_util.tree_leaves(g_t)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                                   err_msg=f"{(pipe, m)} {path}")
    print("skip-model grid point OK", pipe, m)

# --- 2. streamed inputs through the fused executor: bitwise --------------
l0, g0 = whisper_lg("1f1b", 4, 8, stream=False)
l1, g1 = whisper_lg("1f1b", 4, 8, stream=True)
assert np.array_equal(l0, l1), (l0, l1)
for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                        jax.tree_util.tree_leaves(g1)):
    assert np.array_equal(a, b), path
print("streamed fused OK")

# --- 3. resident state threaded through an F+B step ----------------------
n, m, mb, D = 2, 4, 2, 8
pcfg = ParallelConfig(pipe=n, tp=1, data=1, pod=1, n_micro=m,
                      schedule="1f1b", remat="full")
mesh = mesh_lib.make_smoke_mesh(pcfg)
W = jax.random.normal(key, (n, D, D)) * 0.3
x = jax.random.normal(jax.random.fold_in(key, 1), (m, mb, D))
labels = jax.random.normal(jax.random.fold_in(key, 2), (m, mb, D))

def stage_apply(p, carry, skips_in, resident, ctx):
    h = jnp.where(ctx.stage == 0, ctx.fresh["h"], carry["h"])
    h2 = jnp.tanh(h @ p)
    res = dict(resident)
    if "seen" in res:
        res["seen"] = jax.lax.dynamic_update_index_in_dim(
            resident["seen"], jnp.mean(h2), ctx.micro, 0)
    return {"h": h2}, {}, res

def loss_fn(hp, carry, la):
    return jnp.mean((carry["h"] - la["y"]) ** 2)

tplan = plan_lib.plan_for("1f1b", m, n)

def run(with_res):
    resident = {"seen": jnp.zeros((m,))} if with_res else {}
    def inner(rank, res):
        with compat.manual_region():
            loss, gs, gh, ig, res2 = run_pipeline_tasks(
                stage_apply, W[rank[0]], {"h": x}, pcfg, tplan=tplan,
                head_params={}, loss_args_mb={"y": labels},
                loss_fn=loss_fn, resident=jax.tree.map(lambda a: a[0], res),
                rank=rank[0])
            return (loss[None], jax.tree.map(lambda a: a[None], gs),
                    jax.tree.map(lambda a: a[None], res2))
    fn = compat.shard_map(inner, mesh=mesh, in_specs=(P("pipe"), P("pipe")),
                          out_specs=(P("pipe"), P("pipe"), P("pipe")),
                          axis_names={"pipe"}, check_vma=False)
    rk = jnp.arange(n, dtype=jnp.int32)
    rr = jax.tree.map(lambda a: jnp.stack([a] * n), resident)
    return jax.jit(lambda: fn(rk, rr))()

loss0, g0, _ = run(False)
loss1, g1, res = run(True)
# resident must not perturb the training computation ...
assert np.array_equal(np.asarray(loss0), np.asarray(loss1))
assert np.array_equal(np.asarray(g0), np.asarray(g1))
# ... and must hold each stage's per-micro statistics, updated on F ticks
h = x
expect = []
for j in range(n):
    h = jnp.tanh(h @ W[j])
    expect.append(jnp.mean(h, axis=(1, 2)))
np.testing.assert_allclose(np.asarray(res["seen"]), np.stack(expect),
                           rtol=1e-6)
print("resident fused OK")
print("UNIFIED EXTRAS OK")
"""


def test_unified_executor_skips_streaming_resident():
    """The tentpole's acceptance surface: (1) a skip-connection model runs
    the fused F+B schedules with bitwise-identical grads between the
    legacy-lowered GPipe table and 1F1B (and matches the autodiff
    reference); (2) ``stream_inputs`` lowers to plan injection ticks and is
    bitwise vs replicated inputs; (3) resident state threads through an
    F+B step without perturbing gradients."""
    out = run_subprocess(UNIFIED_EXTRAS, n_devices=8, timeout=1800)
    assert "UNIFIED EXTRAS OK" in out
