"""Schedule-driven execution: the fused scheduler (repro.core.plan +
run_pipeline_tasks) must make 1F1B and GPipe *the same computation in a
different order* — bitwise-identical losses and gradients — and must match
the legacy autodiff backward to numerical tolerance.

Host-side plan properties run in-process; executor equivalence runs on 8
XLA host devices in a subprocess (one subprocess amortizes jit time over
the whole (pipe, m) grid)."""
import pytest

from conftest import run_subprocess

from repro.core import plan as PL
from repro.core import schedules as S


# ---------------------------------------------------------------------------
# Plan lowering properties (host-side, no devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 1), (4, 1), (1, 4), (4, 2), (8, 2),
                                 (4, 4), (8, 4), (6, 3)])
def test_plan_stash_matches_peak_stash(m, n):
    """The executor's stash buffer is sized by the plan; the plan's
    per-stage high-water mark must equal schedules.peak_stash exactly."""
    for name, table in (("gpipe", S.gpipe_schedule(m, n, checkpoint=False)),
                        ("1f1b", S.one_f_one_b_schedule(m, n))):
        plan = PL.lower_tasks(table, m, n)
        assert list(plan.per_stage_stash) == S.peak_stash(table, n, m), name
        assert plan.stash_depth == max(plan.per_stage_stash)
    gpipe = PL.plan_for("gpipe", m, n)
    f1b = PL.plan_for("1f1b", m, n)
    assert all(gpipe.per_stage_stash[j] == m for j in range(n))
    assert all(f1b.per_stage_stash[j] <= min(n - j, m) for j in range(n))
    # 1F1B's memory bound is the point: strictly better whenever m > n
    if m > n:
        assert f1b.stash_depth < gpipe.stash_depth


@pytest.mark.parametrize("m,n", [(4, 2), (8, 4), (5, 3)])
def test_plan_task_coverage(m, n):
    """Every F and B task appears exactly once, at most one task per rank
    per tick, and ring arrivals never overtake their consumers."""
    for name in ("gpipe", "1f1b"):
        p = PL.plan_for(name, m, n)
        seen = set()
        for t in range(p.n_ticks):
            for j in range(n):
                k = p.kind[t, j]
                if k == PL.NOP:
                    continue
                task = ("F" if k == PL.FWD else "B", int(p.micro[t, j]), j)
                assert task not in seen, task
                seen.add(task)
                assert p.stash_slot[t, j] >= 0
        assert len(seen) == 2 * m * n, name
        # inbox slot pairing: each recv is read later (or same tick)
        for arr, rd in ((p.f_recv_slot, p.f_read_slot),
                        (p.b_recv_slot, p.b_read_slot)):
            for j in range(n):
                pending = {}
                for t in range(p.n_ticks):
                    if arr[t, j] >= 0:
                        assert arr[t, j] not in pending, "slot overwritten"
                        pending[int(arr[t, j])] = t
                    if rd[t, j] >= 0:
                        assert int(rd[t, j]) in pending, "read before arrival"
                        del pending[int(rd[t, j])]
                assert not pending, "arrival never consumed"


def test_forward_plan_is_clock_cycle():
    """lower_forward reproduces Algorithm 1's F_{t-j, j} arithmetic."""
    m, n = 6, 4
    p = PL.lower_forward(m, n)
    assert p.n_ticks == m + n - 1
    for t in range(p.n_ticks):
        for j in range(n):
            assert p.valid[t, j] == (0 <= t - j < m)
            assert p.micro[t, j] == min(max(t - j, 0), m - 1)


# ---------------------------------------------------------------------------
# Executor equivalence (8 host devices, subprocess)
# ---------------------------------------------------------------------------

EXEC_GRID = """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core.pipeline import (pipeline_call, pipeline_grad_call,
                                 microbatch, last_stage_output, unmicrobatch)

arch = configs.smoke_arch("smollm-360m")
key = jax.random.PRNGKey(0)

def loss_and_grads(schedule, pipe, m, data):
    shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=data, pod=1, n_micro=m,
                          remat="full", schedule=schedule)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {k: jax.random.randint(jax.random.fold_in(key, len(k)),
                                   v.shape, 0, arch.vocab)
             for k, v in model.input_specs(shape).items()}
    consts = model.consts()
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        if schedule == "gpipe":      # legacy autodiff path (reference)
            pipe_fn = pipeline_call(model.make_stage_apply(consts),
                                    mesh=mesh, cfg=pcfg, carry_proto=cp)
            def loss_fn(p, b):
                fresh = model.embed_inputs(p["embed"], b)
                outs, _ = pipe_fn(p["stages"], microbatch(fresh, m), None)
                h = unmicrobatch(last_stage_output(outs)["h"])
                return model.head_loss(p, h, b["labels"])
            loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
            return np.asarray(loss), jax.tree.map(np.asarray, grads)
        pg, tplan = pipeline_grad_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            carry_proto=cp)
        # structural memory bound: the stash buffer depth is decided by the
        # plan, before any tracing
        import repro.core.schedules as S
        expect = ([min(pipe - j, m) for j in range(pipe)]
                  if schedule == "1f1b" else [m] * pipe)
        assert list(tplan.per_stage_stash) == expect, tplan.per_stage_stash
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
        return np.asarray(loss), jax.tree.map(np.asarray, grads)

for pipe, m, data in [(1, 4, 1), (2, 4, 1), (2, 8, 2), (4, 4, 1), (4, 8, 2)]:
    l_t, g_t = loss_and_grads("gpipe_tasked", pipe, m, data)
    l_f, g_f = loss_and_grads("1f1b", pipe, m, data)
    # 1F1B vs GPipe through the fused scheduler: bitwise identical
    assert np.array_equal(l_t, l_f), (pipe, m, data, l_t, l_f)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_t)[0],
                            jax.tree_util.tree_leaves(g_f)):
        assert np.array_equal(a, b), (pipe, m, data, path)
    # fused gpipe vs legacy autodiff gpipe: same math, different graph
    l_r, g_r = loss_and_grads("gpipe", pipe, m, data)
    np.testing.assert_allclose(l_t, l_r, rtol=2e-5)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(g_r)[0],
                            jax.tree_util.tree_leaves(g_t)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                                   err_msg=f"{(pipe, m, data)} {path}")
    print("grid point OK", pipe, m, data)
print("SCHEDULE EXEC EQUIV OK")
"""


def test_1f1b_equals_gpipe_bitwise_and_legacy_close():
    out = run_subprocess(EXEC_GRID, n_devices=8, timeout=1800)
    assert "SCHEDULE EXEC EQUIV OK" in out


TRAIN_1F1B = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=4,
                      schedule="1f1b")
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = LMModel(arch, pcfg, dtype=jnp.float32)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
params = model.init(jax.random.PRNGKey(0))
ocfg = optim.OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)
opt = optim.init(ocfg, params)
with set_mesh(mesh):
    step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))
    batch = {k: jax.random.randint(jax.random.PRNGKey(1), v.shape, 0,
                                   arch.vocab)
             for k, v in model.input_specs(shape).items()}
    losses = []
    for _ in range(6):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0] * 0.9, losses
print("1F1B TRAIN OK", losses[0], "->", losses[-1])
"""


def test_1f1b_train_loop_converges():
    """End-to-end: schedule="1f1b" through build_train_step memorizes a
    fixed batch on an 8-device mesh (pipeline + DP + AdamW)."""
    out = run_subprocess(TRAIN_1F1B, n_devices=8, timeout=900)
    assert "1F1B TRAIN OK" in out
