"""Additional property tests on system invariants (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ParallelConfig, ShapeConfig
from repro import configs
from repro.runtime import elastic
from repro.runtime.compression import EFCompressor
from repro.core import stage as stage_lib


@given(st.integers(1, 128), st.integers(1, 16))
@settings(max_examples=80, deadline=None)
def test_pad_layout_invariants(n_layers, n_stages):
    L, mask = stage_lib.pad_layout(n_layers, n_stages)
    assert mask.shape == (n_stages, L)
    assert int(mask.sum()) == n_layers
    assert n_stages * L >= n_layers
    assert n_stages * (L - 1) < max(n_layers, 1) or L == 1
    flat = mask.reshape(-1)
    # real layers are a prefix: once padding starts it never stops
    first_pad = int(flat.argmin()) if (flat == 0).any() else len(flat)
    assert flat[:first_pad].all() and not flat[first_pad:].any()


@given(st.integers(1, 8).map(lambda k: 2 ** k),
       st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=60, deadline=None)
def test_choose_layout_tiles_pool(pool_pow, tp, old_pipe):
    pool = pool_pow * tp
    old = ParallelConfig(pipe=old_pipe, tp=tp, data=16, pod=1)
    new = elastic.choose_layout(pool, old)
    assert new.pipe * new.data * new.tp == pool
    assert new.tp == tp
    assert new.pipe <= old.pipe


@given(st.integers(0, 10), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_data_tokens_within_vocab(step, vocab):
    from repro.data.pipeline import DataConfig, SyntheticLM
    ds = SyntheticLM(DataConfig(seed=1, vocab=vocab, seq_len=16,
                                global_batch=2))
    b = ds.batch_at(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < vocab
    assert b["labels"].min() >= 0 and b["labels"].max() < vocab


@given(st.lists(st.floats(-10, 10), min_size=1, max_size=300),
       st.sampled_from([16, 64, 256]))
@settings(max_examples=40, deadline=None)
def test_compression_residual_identity(vals, block):
    """quantized + residual == original, exactly (fp32)."""
    comp = EFCompressor(block=block)
    g = {"w": jnp.asarray(np.array(vals, np.float32))}
    ef = comp.init_state(g)
    out, ef2 = comp.compress_reduce(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"] + ef2["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)


@given(st.sampled_from(configs.ARCH_NAMES),
       st.sampled_from(["train_4k", "prefill_32k", "decode_32k"]),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_derived_n_micro_always_legal(arch_name, shape_name, multi_pod):
    from repro.configs.base import SHAPES_BY_NAME
    pcfg = configs.get_parallel(arch_name).with_(pod=2 if multi_pod else 1)
    shape = SHAPES_BY_NAME[shape_name]
    m = configs.derive_n_micro(shape, pcfg)
    dp = pcfg.data * pcfg.pod * pcfg.dp2
    assert shape.global_batch % m == 0
    assert (shape.global_batch // m) % dp == 0 or shape.global_batch < dp
    assert 1 <= m <= shape.global_batch
