"""Roofline HLO analysis: shape parsing, trip-count recovery, dot FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.roofline import analysis as A


def test_shape_bytes():
    assert A.shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert A.shape_bytes("f32[8]") == 32
    assert A.shape_bytes("(f32[4,4]{1,0}, s32[2])") == 64 + 8
    assert A.shape_bytes("pred[]") == 1


def test_trip_count_correction_on_scan():
    """XLA counts while bodies once; the analyzer must multiply by the trip
    count recovered from the loop condition."""
    D, T = 64, 10

    def scanned(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, D, D), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    xla_flops = compat.cost_analysis(compiled)["flops"]
    cost = A.analyze_hlo(compiled.as_text(), 1)
    expect_dot = 2 * 32 * D * D * T
    # XLA undercounts by ~T; ours is within 1% of analytic
    assert xla_flops < expect_dot / 2
    assert abs(cost.flops - expect_dot) / expect_dot < 0.01


def test_nested_scan_multipliers():
    D, T1, T2 = 32, 5, 7

    def inner(x, ws):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, ws)[0]

    def outer(x, ws):
        def body(x, _):
            return inner(x, ws), None
        return jax.lax.scan(body, x, jnp.arange(T1))[0]

    x = jax.ShapeDtypeStruct((8, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((T2, D, D), jnp.float32)
    compiled = jax.jit(outer).lower(x, ws).compile()
    cost = A.analyze_hlo(compiled.as_text(), 1)
    expect = 2 * 8 * D * D * T1 * T2
    assert abs(cost.flops - expect) / expect < 0.02


def test_dot_flops_contraction_dim():
    ins = A.Instr("d", "f32[16,32]", "dot",
                  "%d = f32[16,32]{1,0} dot(%a, %b), lhs_contracting_dims={1},"
                  " rhs_contracting_dims={0}")
    symtab = {"a": "f32[16,64]", "b": "f32[64,32]"}
    assert A._dot_flops(ins, symtab) == 2 * 16 * 32 * 64


def test_vmem_score_rule():
    assert A._is_vmem_score("f32[15,4096,512]{2,1,0}")       # score block
    assert not A._is_vmem_score("bf16[15,4096,512]")         # bf16 => data
    assert not A._is_vmem_score("f32[4096,960]")             # 2-dim weight
    assert not A._is_vmem_score("f32[256,512,49152]")        # logits (big last)


def test_collective_ring_factors():
    c = A.Collective = None  # module keeps no Collective class anymore
    # ring factors via analyze on a synthetic line set
    hlo = """
HloModule m, num_partitions=4

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[64]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cost = A.analyze_hlo(hlo, 4)
    assert cost.coll_link_bytes["all-reduce"] == pytest.approx(
        2 * 256 * 3 / 4)
    assert cost.coll_link_bytes["collective-permute"] == 256


def test_model_flops_moe_uses_active_params():
    from repro import configs
    from repro.configs.base import TRAIN_4K
    mix = configs.get_arch("mixtral-8x7b")
    dense_equiv = mix.total_params()
    active = mix.active_params_per_token()
    assert active < dense_equiv / 2          # top-2 of 8 experts
    f = A.model_flops_for(mix, TRAIN_4K)
    assert f == pytest.approx(6.0 * active * 256 * 4096)
