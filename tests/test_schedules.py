"""Schedule properties: Algorithm 1 (deterministic clock-cycle), GPipe
forward+backward ordering, 1F1B, bubble fractions, stash bounds."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedules as S

mn = st.tuples(st.integers(1, 24), st.integers(1, 12))


@given(mn)
@settings(max_examples=60, deadline=None)
def test_clock_cycle_is_algorithm_1(m_n):
    """Tick k runs exactly the tasks F[i,j] with i + j == k (paper Alg. 1)."""
    m, n = m_n
    ticks = list(S.clock_cycles(m, n))
    assert len(ticks) == m + n - 1
    seen = set()
    for k, tick in enumerate(ticks):
        for t in tick:
            assert t.kind == "F"
            assert t.micro + t.stage == k
            assert 0 <= t.micro < m and 0 <= t.stage < n
            seen.add((t.micro, t.stage))
    assert len(seen) == m * n


@given(mn, st.booleans())
@settings(max_examples=40, deadline=None)
def test_gpipe_schedule_valid(m_n, recompute_last):
    m, n = m_n
    table = S.gpipe_schedule(m, n, checkpoint=True,
                             recompute_last_micro=recompute_last)
    S.validate(table, m, n, checkpoint=True,
               recompute_last_micro=recompute_last)


@given(mn)
@settings(max_examples=40, deadline=None)
def test_1f1b_schedule_valid(m_n):
    m, n = m_n
    table = S.one_f_one_b_schedule(m, n)
    # 1F1B reorders backwards across micro-batches by design
    S.validate(table, m, n, checkpoint=False, backward_micro_order=False)


@given(mn)
@settings(max_examples=40, deadline=None)
def test_1f1b_stash_bound(m_n):
    """1F1B bounds live activations per stage by min(n - j, m); GPipe
    stashes the full m on every stage — the paper's memory motivation."""
    m, n = m_n
    peak_1f1b = S.peak_stash(S.one_f_one_b_schedule(m, n), n, m)
    peak_gpipe = S.peak_stash(S.gpipe_schedule(m, n, checkpoint=False), n, m)
    for j in range(n):
        assert peak_1f1b[j] <= min(n - j, m)
        assert peak_gpipe[j] == m
        assert peak_1f1b[j] <= peak_gpipe[j]


def test_last_microbatch_recompute_elided():
    """Paper §2.1: F'_{m,j} is unnecessary and omitted by default."""
    m, n = 4, 3
    table = S.gpipe_schedule(m, n, checkpoint=True)
    recs = [t for tick in table for t in tick if t.kind == "R"]
    assert all(t.micro != m - 1 for t in recs)
    assert len(recs) == (m - 1) * n
    # footnote 5: m=1 with forced recompute => checkpointing still applies
    table1 = S.gpipe_schedule(1, n, checkpoint=True,
                              recompute_last_micro=True)
    recs1 = [t for tick in table1 for t in tick if t.kind == "R"]
    assert len(recs1) == n


def test_bubble_fraction():
    assert S.bubble_fraction(1, 1) == 0.0
    assert S.bubble_fraction(4, 3) == pytest.approx(2 / 6)
    # GPipe guidance: m >= 4n keeps bubble under 20%
    assert S.bubble_fraction(4 * 8, 8) < 0.2


@given(mn)
@settings(max_examples=30, deadline=None)
def test_backward_is_reverse_clock_cycle(m_n):
    """The autodiff-induced backward runs B[i,j] at reverse tick
    (m-1-i)+(n-1-j) — the mirror of Algorithm 1 (paper Fig. 2)."""
    m, n = m_n
    for k, tick in enumerate(S.gpipe_backward_cycles(m, n, checkpoint=False)):
        for t in tick:
            assert (m - 1 - t.micro) + (n - 1 - t.stage) == k
