"""Schedule properties: Algorithm 1 (deterministic clock-cycle), GPipe
forward+backward ordering, 1F1B, interleaved virtual stages, split-backward
(zero-bubble), bubble fractions, stash bounds."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedules as S
from repro.core.schedules import Task

mn = st.tuples(st.integers(1, 24), st.integers(1, 12))
# interleaved needs m % n == 0: draw (waves, n, v) and build m = waves * n
wnv = st.tuples(st.integers(1, 4), st.integers(1, 6), st.integers(2, 3))


def ticks_of(table):
    return {t: k for k, tick in enumerate(table) for t in tick}


@given(mn)
@settings(max_examples=60, deadline=None)
def test_clock_cycle_is_algorithm_1(m_n):
    """Tick k runs exactly the tasks F[i,j] with i + j == k (paper Alg. 1)."""
    m, n = m_n
    ticks = list(S.clock_cycles(m, n))
    assert len(ticks) == m + n - 1
    seen = set()
    for k, tick in enumerate(ticks):
        for t in tick:
            assert t.kind == "F"
            assert t.micro + t.stage == k
            assert 0 <= t.micro < m and 0 <= t.stage < n
            seen.add((t.micro, t.stage))
    assert len(seen) == m * n


@given(mn, st.booleans())
@settings(max_examples=40, deadline=None)
def test_gpipe_schedule_valid(m_n, recompute_last):
    m, n = m_n
    table = S.gpipe_schedule(m, n, checkpoint=True,
                             recompute_last_micro=recompute_last)
    S.validate(table, m, n, checkpoint=True,
               recompute_last_micro=recompute_last)


@given(mn)
@settings(max_examples=40, deadline=None)
def test_1f1b_schedule_valid(m_n):
    m, n = m_n
    table = S.one_f_one_b_schedule(m, n)
    # 1F1B reorders backwards across micro-batches by design
    S.validate(table, m, n, checkpoint=False, backward_micro_order=False)


@given(mn)
@settings(max_examples=40, deadline=None)
def test_1f1b_stash_bound(m_n):
    """1F1B bounds live activations per stage by min(n - j, m); GPipe
    stashes the full m on every stage — the paper's memory motivation."""
    m, n = m_n
    peak_1f1b = S.peak_stash(S.one_f_one_b_schedule(m, n), n)
    peak_gpipe = S.peak_stash(S.gpipe_schedule(m, n, checkpoint=False), n)
    for j in range(n):
        assert peak_1f1b[j] <= min(n - j, m)
        assert peak_gpipe[j] == m
        assert peak_1f1b[j] <= peak_gpipe[j]


# ---------------------------------------------------------------------------
# Interleaved virtual stages
# ---------------------------------------------------------------------------

@given(wnv)
@settings(max_examples=40, deadline=None)
def test_interleaved_valid_and_ordered(wnv_):
    """Every (i, global stage) F precedes its B; the table covers all
    m * n * v tasks; one task per RANK per tick (chunks share a rank)."""
    w, n, v = wnv_
    m = w * n
    table = S.interleaved_1f1b_schedule(m, n, v)
    S.validate(table, m, n * v, ranks=n, backward_micro_order=False)
    seen = ticks_of(table)
    for i in range(m):
        for s in range(n * v):
            assert seen[Task("F", i, s)] < seen[Task("B", i, s)]


@given(wnv)
@settings(max_examples=30, deadline=None)
def test_interleaved_stash_and_bubble(wnv_):
    """Per-rank peak stash is bounded by m*v, and the bubble fraction does
    not exceed plain 1F1B's on the same (m, n) — the interleaving payoff."""
    w, n, v = wnv_
    m = w * n
    table = S.interleaved_1f1b_schedule(m, n, v)
    peak = S.peak_stash(table, n * v, ranks=n)
    assert all(p <= m * v for p in peak)
    b_il = S.bubble_fraction(table, ranks=n)
    b_1f = S.bubble_fraction(S.one_f_one_b_schedule(m, n))
    assert b_il <= b_1f + 1e-9
    if n > 1 and v > 1:
        assert b_il < b_1f    # strictly fewer idle slots


def test_interleaved_degenerates_and_rejects():
    assert S.interleaved_1f1b_schedule(8, 4, 1) \
        == S.one_f_one_b_schedule(8, 4)
    with pytest.raises(ValueError):
        S.interleaved_1f1b_schedule(6, 4, 2)     # m % n != 0
    with pytest.raises(ValueError):
        S.interleaved_1f1b_schedule(8, 4, 0)


# ---------------------------------------------------------------------------
# Split backward (zero-bubble)
# ---------------------------------------------------------------------------

@given(mn)
@settings(max_examples=40, deadline=None)
def test_zb_valid_and_bw_after_bx(m_n):
    """Bx inherits B's chain; Bw(i,j) never precedes its Bx(i,j); every
    F/Bx/Bw appears exactly once."""
    m, n = m_n
    table = S.zb_schedule(m, n)
    S.validate(table, m, n, backward_micro_order=False)
    seen = ticks_of(table)
    for i in range(m):
        for j in range(n):
            assert seen[Task("F", i, j)] < seen[Task("Bx", i, j)]
            assert seen[Task("Bx", i, j)] < seen[Task("Bw", i, j)]
    assert len(seen) == 3 * m * n


@given(mn)
@settings(max_examples=30, deadline=None)
def test_zb_fills_bubbles(m_n):
    """The Bw fill gives zb a bubble fraction <= 1F1B's (strictly smaller
    whenever 1F1B has a bubble at all and there is real pipelining)."""
    m, n = m_n
    b_zb = S.bubble_fraction(S.zb_schedule(m, n))
    b_1f = S.bubble_fraction(S.one_f_one_b_schedule(m, n))
    assert b_zb <= b_1f + 1e-9
    if n > 1 and m >= n:
        assert b_zb < b_1f


@given(mn)
@settings(max_examples=30, deadline=None)
def test_zb_stash_freed_at_bw(m_n):
    """Split backward holds the activation until Bw (the weight grad still
    needs the stage input after Bx) — the bound stays 1F1B-shaped + the
    Bx->Bw gap, never exceeding m."""
    m, n = m_n
    peak = S.peak_stash(S.zb_schedule(m, n), n)
    for j in range(n):
        assert peak[j] <= m


# ---------------------------------------------------------------------------
# Bubble fraction (table-driven) + validate rejections
# ---------------------------------------------------------------------------

def test_bubble_fraction_from_table():
    """bubble_fraction counts idle slots in the actual table: GPipe's
    matches the paper's closed form, 1F1B matches GPipe (same tick count),
    and the new schedules undercut both."""
    for m, n in [(4, 3), (8, 4), (32, 8), (1, 1)]:
        g = S.bubble_fraction(S.gpipe_schedule(m, n, checkpoint=False))
        assert g == pytest.approx(S.ideal_bubble_fraction(m, n))
        f = S.bubble_fraction(S.one_f_one_b_schedule(m, n))
        assert f == pytest.approx(g)
    assert S.ideal_bubble_fraction(1, 1) == 0.0
    assert S.ideal_bubble_fraction(4, 3) == pytest.approx(2 / 6)
    # GPipe guidance: m >= 4n keeps bubble under 20%
    assert S.ideal_bubble_fraction(4 * 8, 8) < 0.2
    # interleaving / Bw-filling shrink the bubble at fixed (m, n)
    f = S.bubble_fraction(S.one_f_one_b_schedule(8, 4))
    assert S.bubble_fraction(S.interleaved_1f1b_schedule(8, 4, 2),
                             ranks=4) < f
    assert S.bubble_fraction(S.zb_schedule(8, 4)) < f


def test_validate_rejects_malformed_tables():
    m, n, v = 4, 2, 2
    ok = S.interleaved_1f1b_schedule(m, n, v)
    # drop one backward task
    broken = [[t for t in tick if t != Task("B", 0, 1)] for tick in ok]
    with pytest.raises(AssertionError):
        S.validate(broken, m, n * v, ranks=n, backward_micro_order=False)
    # two tasks for one rank in one tick (chunks collide)
    broken = [list(tick) for tick in ok]
    broken[0].append(Task("F", 0, 2))     # stage 2 = rank 0 chunk 1
    with pytest.raises(AssertionError):
        S.validate(broken, m, n * v, ranks=n, backward_micro_order=False)
    # F after its B
    zb = S.zb_schedule(4, 2)
    flip = [[Task("Bw", 0, 0)]] + [
        [t for t in tick if t != Task("Bw", 0, 0)] for tick in zb]
    with pytest.raises(AssertionError):
        S.validate(flip, 4, 2, backward_micro_order=False)


def test_last_microbatch_recompute_elided():
    """Paper §2.1: F'_{m,j} is unnecessary and omitted by default."""
    m, n = 4, 3
    table = S.gpipe_schedule(m, n, checkpoint=True)
    recs = [t for tick in table for t in tick if t.kind == "R"]
    assert all(t.micro != m - 1 for t in recs)
    assert len(recs) == (m - 1) * n
    # footnote 5: m=1 with forced recompute => checkpointing still applies
    table1 = S.gpipe_schedule(1, n, checkpoint=True,
                              recompute_last_micro=True)
    recs1 = [t for tick in table1 for t in tick if t.kind == "R"]
    assert len(recs1) == n


@given(mn)
@settings(max_examples=30, deadline=None)
def test_backward_is_reverse_clock_cycle(m_n):
    """The autodiff-induced backward runs B[i,j] at reverse tick
    (m-1-i)+(n-1-j) — the mirror of Algorithm 1 (paper Fig. 2)."""
    m, n = m_n
    for k, tick in enumerate(S.gpipe_backward_cycles(m, n, checkpoint=False)):
        for t in tick:
            assert (m - 1 - t.micro) + (n - 1 - t.stage) == k


@given(mn, st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_comm_term_overlap_dominance(m_n, comm):
    """The device model's comm term: for every table, the overlapped
    (mpmd double-buffered) critical path is <= the serialized (spmd) one,
    both are >= the zero-comm legacy clock, and comm_cost=0 reduces to it
    exactly.  Busy time stays compute-only, so the spmd bubble (comm
    stalls included as idle) is >= the mpmd bubble."""
    m, n = m_n
    for table in (S.one_f_one_b_schedule(m, n), S.zb_schedule(m, n),
                  S.gpipe_schedule(m, n, checkpoint=False)):
        t0, busy0 = S.simulate_device_times(table, n)
        tz, busyz = S.simulate_device_times(table, n, comm_cost=0.0,
                                            overlap_comm=True)
        assert t0 == pytest.approx(tz) and busy0 == pytest.approx(busyz)
        ts, busys = S.simulate_device_times(table, n, comm_cost=comm)
        tm, busym = S.simulate_device_times(table, n, comm_cost=comm,
                                            overlap_comm=True)
        assert tm <= ts + 1e-9
        assert t0 <= tm + 1e-9
        # busy is compute-only in both stories
        assert busys == pytest.approx(busy0)
        assert busym == pytest.approx(busy0)
        if n > 1:
            assert S.device_bubble_fraction(table, n, comm_cost=comm,
                                            overlap_comm=True) \
                <= S.device_bubble_fraction(table, n, comm_cost=comm) + 1e-9
