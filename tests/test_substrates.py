"""Substrate tests: optimizer, data, checkpoint, fault tolerance, elastic,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.optim import optimizers as optim
from repro.runtime import elastic
from repro.runtime.compression import EFCompressor
from repro.runtime.fault_tolerance import (FaultInjector, Preemption,
                                           StepWatchdog, Supervisor)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = optim.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, clip_norm=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = optim.init(cfg, params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = optim.apply(cfg, state, params, g)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adam_bias_correction_first_step():
    """After one step with clip off, update = -lr * sign-ish of grad."""
    cfg = optim.OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                                weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.zeros(4)}
    state = optim.init(cfg, params)
    g = {"w": jnp.asarray([1.0, -1.0, 2.0, -2.0])}
    params, state, _ = optim.apply(cfg, state, params, g)
    # update ~= -lr * sign(g) (cosine schedule already active at step 1)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               -1e-2 * np.sign([1, -1, 2, -2]), rtol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(6.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_sgd_momentum_and_master_dtype():
    cfg = optim.OptimizerConfig(name="sgd", lr=0.1, momentum=0.9,
                                warmup_steps=0, weight_decay=0.0,
                                clip_norm=0.0)
    params = {"w": jnp.zeros(2, jnp.bfloat16)}
    state = optim.init(cfg, params)
    assert state.master["w"].dtype == jnp.float32
    g = {"w": jnp.ones(2, jnp.bfloat16)}
    params, state, _ = optim.apply(cfg, state, params, g)
    assert params["w"].dtype == jnp.bfloat16
    # momentum accumulates: second step moves further
    p1 = float(params["w"][0])
    params, state, _ = optim.apply(cfg, state, params, g)
    assert float(params["w"][0]) < p1 * 2 < 0


def test_lr_schedule_shape():
    cfg = optim.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
    lrs = [float(optim.schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1.0, rel=1e-3)
    assert lrs[100] == pytest.approx(0.1, rel=1e-2)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_pure_function_of_step():
    cfg = DataConfig(seed=7, vocab=100, seq_len=32, global_batch=4)
    ds = SyntheticLM(cfg)
    a = ds.batch_at(13)
    b = ds.batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(14)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 100
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_data_restart_replays_stream():
    cfg = DataConfig(seed=3, vocab=64, seq_len=8, global_batch=2)
    ds = SyntheticLM(cfg)
    full = [b["tokens"] for _, b in zip(range(6), ds.stream(0))]
    resumed = [b["tokens"] for _, b in zip(range(3), ds.stream(3))]
    for i in range(3):
        np.testing.assert_array_equal(full[3 + i], resumed[i])


def test_prefetcher_order_and_close():
    it = iter(range(10))
    pf = Prefetcher(it, depth=2)
    got = [next(pf) for _ in range(5)]
    assert got == list(range(5))
    pf.close()


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(x):
    return {"a": jnp.asarray([x, x + 1.0]), "b": {"c": jnp.asarray(x * 2.0)}}


def test_ckpt_roundtrip_bitwise(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree(3.0)
    mgr.save(5, t)
    got, meta = mgr.restore(5, jax.tree.map(lambda x: x, t))
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    assert mgr.latest_step() == 4
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".COMMIT"))
    assert kept == ["step_000003.COMMIT", "step_000004.COMMIT"]


def test_ckpt_uncommitted_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    os.remove(os.path.join(tmp_path, "step_000002.COMMIT"))
    assert mgr.latest_step() == 1


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(7, _tree(7.0))
    mgr.wait()
    got, meta = mgr.restore(7, _tree(0.0))
    assert meta["step"] == 7


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_supervisor_restarts_and_resumes(tmp_path):
    """Inject preemptions mid-run; training must resume from the last commit
    and produce the identical final state as a fault-free run."""
    def build(ckpt_dir, faults):
        mgr = CheckpointManager(str(ckpt_dir), async_write=False)

        def make_state(restored):
            return restored if restored is not None else {
                "w": jnp.zeros(2), "step_sum": jnp.zeros(())}

        def step_fn(state, step):
            new = {"w": state["w"] + 1.0,
                   "step_sum": state["step_sum"] + step}
            return new, {"w0": float(new["w"][0])}

        return Supervisor(ckpt=mgr, make_state=make_state, step_fn=step_fn,
                          ckpt_every=4,
                          injector=FaultInjector(fail_at_steps=faults))

    clean = build(tmp_path / "clean", ()).run(20)
    faulty = build(tmp_path / "faulty", (6, 13)).run(20)
    assert faulty["restarts"] == 2
    np.testing.assert_allclose(np.asarray(clean["state"]["w"]),
                               np.asarray(faulty["state"]["w"]))
    np.testing.assert_allclose(np.asarray(clean["state"]["step_sum"]),
                               np.asarray(faulty["state"]["step_sum"]))


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    sup = Supervisor(
        ckpt=mgr, make_state=lambda r: r or {"w": jnp.zeros(1)},
        step_fn=lambda s, i: (_ for _ in ()).throw(Preemption("always")),
        max_restarts=2)
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(4)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=4, multiple=3.0)
    for i in range(8):
        wd.observe(i, 0.01)
    wd.observe(8, 0.5)
    assert wd.stragglers == [8]


# ---------------------------------------------------------------------------
# Elastic re-scaling
# ---------------------------------------------------------------------------

def test_choose_layout_shrinks_pool():
    old = elastic.ParallelConfig(pipe=8, tp=2, data=16, pod=1)
    new = elastic.choose_layout(128, old)     # lost half the pool
    assert new.tp == 2 and new.pipe * new.data * new.tp == 128
    assert new.pipe <= 8


def test_restack_preserves_layers():
    import numpy as np
    from repro.core import stage as stage_lib
    layer_vals = [jnp.full((2, 2), float(i)) for i in range(6)]
    stacked = stage_lib.stack_layer_params(layer_vals, 4)   # 4 stages, pad 2
    _, mask = stage_lib.pad_layout(6, 4)
    restacked, new_mask = elastic.restack_stages(stacked, mask, 2)
    assert restacked.shape[:2] == (2, 3)
    flat = np.asarray(restacked).reshape(6, 2, 2)
    for i in range(6):
        np.testing.assert_array_equal(flat[i], np.full((2, 2), float(i)))
    assert new_mask.sum() == 6


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------

def test_compression_payload_4x_smaller():
    comp = EFCompressor(block=256)
    g = {"w": jnp.ones((1024, 64))}
    c, raw = comp.payload_bytes(g)
    assert raw / c > 3.5


def test_error_feedback_is_unbiased_over_steps():
    """EF guarantees sum of compressed grads -> sum of true grads."""
    comp = EFCompressor(block=64)
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (256,)) * 0.1}
    ef = comp.init_state(g_true)
    acc = jnp.zeros(256)
    n = 50
    for _ in range(n):
        out, ef = comp.compress_reduce(g_true, ef)
        acc = acc + out["w"]
    # total applied == n * g  minus the final residual (bounded by 1 quantum)
    err = np.abs(np.asarray(acc - n * g_true["w"]))
    assert err.max() < np.abs(np.asarray(g_true["w"])).max() * 1.01


def test_compression_roundtrip_accuracy():
    comp = EFCompressor(block=64)
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (512,))}
    ef = comp.init_state(g)
    out, ef2 = comp.compress_reduce(g, ef)
    rel = np.abs(np.asarray(out["w"] - g["w"])) / (np.abs(np.asarray(g["w"])) + 1e-6)
    assert np.median(rel) < 0.02      # int8 ~ 0.4% quantization noise
    # residual captured exactly
    np.testing.assert_allclose(np.asarray(out["w"] + ef2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
