"""End-to-end system behaviour: the full stack (data pipeline -> pipelined
train step -> optimizer -> async checkpoint -> preemption -> restart)
integrated, on a reduced model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import set_mesh
from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim
from repro.runtime.fault_tolerance import FaultInjector, Supervisor


def _build(name="smollm-360m"):
    arch = configs.smoke_arch(name)
    pcfg = configs.smoke_parallel(name)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    data = SyntheticLM(DataConfig(seed=11, vocab=arch.vocab, seq_len=16,
                                  global_batch=4))
    with set_mesh(mesh):
        step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))
    return model, ocfg, data, step, mesh


def test_train_ckpt_preempt_restart_is_exact(tmp_path):
    """A run preempted twice must reach the SAME final params as a clean
    run: batches are pure functions of step, checkpoints commit atomically,
    and the supervisor resumes at the right step."""
    model, ocfg, data, step, mesh = _build()

    def make_runner(ckpt_dir, faults):
        mgr = CheckpointManager(str(ckpt_dir), async_write=False)

        def make_state(restored):
            if restored is not None:
                return restored
            params = model.init(jax.random.PRNGKey(0))
            return {"params": params, "opt": optim.init(ocfg, params)}

        def step_fn(state, i):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            with set_mesh(mesh):
                p, o, m = step(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, {"loss": float(m["loss"])}

        return Supervisor(ckpt=mgr, make_state=make_state, step_fn=step_fn,
                          ckpt_every=3,
                          injector=FaultInjector(fail_at_steps=faults))

    clean = make_runner(tmp_path / "clean", ()).run(10)
    faulty = make_runner(tmp_path / "faulty", (4, 8)).run(10)
    assert faulty["restarts"] == 2
    for a, b in zip(jax.tree.leaves(clean["state"]["params"]),
                    jax.tree.leaves(faulty["state"]["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # all steps executed; replayed steps (since last commit) are expected
    steps_seen = [h["step"] for h in faulty["history"]]
    assert set(steps_seen) == set(range(10))
    assert steps_seen[-1] == 9


def test_loss_decreases_over_fixed_batch():
    model, ocfg, data, step, mesh = _build("gemma-2b")
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.init(ocfg, params)
    losses = []
    with set_mesh(mesh):
        for i in range(8):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_elastic_restack_preserves_function():
    """Re-partition trained stage weights to a different pipe degree (lost
    devices); the model function must be identical (same loss).  Runs in a
    subprocess with 8 host devices (the shrunken mesh needs >1 device)."""
    from conftest import run_subprocess
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.configs.base import ShapeConfig
from repro.compat import set_mesh
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.runtime import elastic
from repro.core.pipeline import (pipeline_call, microbatch,
                                 last_stage_output, unmicrobatch)

name = "deepseek-7b"
arch = configs.smoke_arch(name)
shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
data = SyntheticLM(DataConfig(seed=5, vocab=arch.vocab, seq_len=16,
                              global_batch=4))
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

def loss_with(pcfg, params):
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    with set_mesh(mesh):
        consts = model.consts()
        mbg = shape.global_batch // pcfg.n_micro
        pipe = pipeline_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            carry_proto={"h": jax.ShapeDtypeStruct(
                (mbg, 16, arch.d_model), jnp.float32)})
        @jax.jit
        def loss(params, batch):
            fresh = model.embed_inputs(params["embed"], batch)
            outs, _ = pipe(params["stages"],
                           microbatch(fresh, pcfg.n_micro), None)
            h = unmicrobatch(last_stage_output(outs)["h"])
            return model.head_loss(params, h, batch["labels"])
        return float(loss(params, batch))

# train-time layout: 4 stages; "failure" shrinks the pool to 2 stages
p1 = configs.smoke_parallel(name).with_(pipe=4, n_micro=2)
model1 = LMModel(arch, p1, dtype=jnp.float32)
params = model1.init(jax.random.PRNGKey(0))
l1 = loss_with(p1, params)
new_layout = elastic.choose_layout(2, p1)
assert new_layout.pipe == 2
restacked, _ = elastic.restack_stages(params["stages"], model1.layer_mask,
                                      new_layout.pipe)
l2 = loss_with(new_layout.with_(n_micro=2), dict(params, stages=restacked))
np.testing.assert_allclose(l1, l2, rtol=2e-5)
print("ELASTIC OK", l1, l2)
""", n_devices=8, timeout=600)
