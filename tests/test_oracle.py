"""Independent single-device correctness oracle for the fused runtime.

The schedule-equivalence suite (tests/test_schedule_exec.py) is
self-referential: gpipe_tasked / 1f1b / zb / interleaved are compared
bitwise against *each other*, so a bug shared by the fused executor's vjp
path would pass every test.  This module checks every fused schedule —
including zb with residual REUSE and RECOMPUTE — against a from-scratch
single-device reference: no pipeline, no shard_map, no task plan, just the
model's stage functions chained sequentially per micro-batch and
``jax.grad`` through the whole thing.

Three model families cover the runtime surface: the plain LM path, the
whisper encoder-decoder (skip portals), and the U-Net heterogeneous
(switch-based) program via ``UNetModel.apply_sequential``.  The LM test
additionally checks loss-curve agreement over 5 optimizer steps.
"""
from conftest import run_subprocess

# Per-dtype allclose tolerances: the oracle and the pipeline evaluate the
# same math on different graphs (fused remat + buffered operands vs one
# autodiff pass), so sums reassociate.
#
# The whole suite honours REPRO_EXECUTOR ("spmd" default / "mpmd"): the CI
# executor-matrix leg reruns every oracle comparison with the fused side
# lowered to per-rank specialized programs, so the MPMD path is checked
# against the independent single-device reference, not just against SPMD.
COMMON = """
import os
import numpy as np
import jax, jax.numpy as jnp

EXECUTOR = os.environ.get("REPRO_EXECUTOR", "spmd")
print("oracle executor:", EXECUTOR)

TOL = {"float32": dict(rtol=5e-4, atol=5e-5),
       "bfloat16": dict(rtol=2e-2, atol=2e-2)}

def assert_close(oracle, got, tag):
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(oracle)[0],
                            jax.tree_util.tree_leaves(got)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            **TOL[str(np.asarray(a).dtype)], err_msg=f"{tag} {path}")

def assert_bitwise(ga, gb, tag):
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(ga)[0],
                            jax.tree_util.tree_leaves(gb)):
        assert np.array_equal(a, b), (tag, path)
"""

LM_ORACLE = COMMON + """
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core.pipeline import (TickCtx, pipeline_grad_call, microbatch,
                                 unmicrobatch)

ARCH = __ARCH__
arch = configs.smoke_arch(ARCH)
key = jax.random.PRNGKey(0)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")

def make_batch(model):
    batch = {}
    for k, v in model.input_specs(shape).items():
        kk = jax.random.fold_in(key, len(k))
        batch[k] = (jax.random.randint(kk, v.shape, 0, arch.vocab)
                    if v.dtype == jnp.int32
                    else jax.random.normal(kk, v.shape, v.dtype) * 0.1)
    return batch

def oracle_loss_fn(model, m):
    # Sequential single-device reference: stage chain per micro-batch,
    # skips held in a plain dict, mean of per-micro losses — mirrors the
    # fused loss contract with zero pipeline machinery.
    sk = model.skips()
    stage_apply = model.make_stage_apply(model.consts())

    def loss_fn(params, batch):
        fresh = model.embed_inputs(params["embed"], batch)
        fresh_mb = jax.tree.map(
            lambda a: a.reshape((m, a.shape[0] // m) + a.shape[1:]), fresh)
        labels_mb = batch["labels"].reshape(
            (m, batch["labels"].shape[0] // m) + batch["labels"].shape[1:])
        hp = {"head": params["head"], "embed": params["embed"]}
        total = jnp.zeros((), jnp.float32)
        for i in range(m):
            fresh_i = jax.tree.map(lambda a: a[i], fresh_mb)
            carry = {"h": jnp.zeros_like(fresh_i["h"])}
            store = {}
            for s in range(model.n_stages):
                skips_in = {e.name: store[e.name] for e in sk
                            if s in e.dsts and e.name in store}
                ctx = TickCtx(stage=jnp.int32(s), micro=jnp.int32(i),
                              valid=jnp.asarray(True), t=jnp.int32(0),
                              fresh=fresh_i, n_stages=model.n_stages,
                              n_micro=m)
                p_s = jax.tree.map(lambda a: a[s], params["stages"])
                carry, skips_out, _ = stage_apply(p_s, carry, skips_in,
                                                  {}, ctx)
                for e in sk:
                    if e.src_stage == s:
                        store[e.name] = skips_out[e.name].astype(model.dtype)
            total = total + model.head_loss(
                hp, carry["h"], labels_mb[i]).astype(jnp.float32)
        return total / m
    return loss_fn

def fused_lg(schedule, m, residuals, remat, remat_last_micro=False):
    pcfg = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=m,
                          remat=remat, schedule=schedule,
                          residuals=residuals, executor=EXECUTOR,
                          remat_last_micro=remat_last_micro)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = make_batch(model)
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    info = {}
    with set_mesh(mesh):
        pg, _ = pipeline_grad_call(
            model.make_stage_apply(model.consts()), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hpp, c, la: model.head_loss(hpp, c["h"],
                                                       la["labels"]),
            skips=model.skips(),
            skip_protos=model.skip_protos(mbg, 16),
            carry_proto=cp, resid_info=info)
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            hpp = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], hpp, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
    return (np.asarray(loss), jax.tree.map(np.asarray, grads),
            model, params, batch, info)

m = 4
results = {}
MATRIX = [("gpipe_tasked", "recompute", "full"),
          ("1f1b", "recompute", "full"),
          ("interleaved:2", "recompute", "full"),
          ("zb", "recompute", "full"),
          ("zb", "reuse", "dots"),
          ("zb", "reuse", "none")]
for schedule, residuals, remat in MATRIX:
    loss, grads, model, params, batch, info = fused_lg(
        schedule, m, residuals, remat)
    if residuals == "reuse" and remat != "full":
        assert info["resid_bytes_per_slot"] > 0, info  # machinery engaged
    o_loss, o_grads = jax.jit(jax.value_and_grad(
        oracle_loss_fn(model, m)))(params, batch)
    np.testing.assert_allclose(np.asarray(o_loss), loss, rtol=2e-5)
    assert_close(o_grads, grads, (ARCH, schedule, residuals, remat))
    results[(schedule, residuals, remat)] = (loss, grads)
    print("oracle OK", ARCH, schedule, residuals, remat)

# acceptance: zb reuse (dots policy) is BITWISE against zb recompute
l_rec, g_rec = results[("zb", "recompute", "full")]
l_reu, g_reu = results[("zb", "reuse", "dots")]
assert np.array_equal(l_rec, l_reu)
assert_bitwise(g_rec, g_reu, "zb-reuse-vs-recompute")

# remat_last_micro is an unrolled-legacy knob: it must not perturb the
# fused reuse path (edge-case satellite)
l_rl, g_rl, *_ = fused_lg("zb", m, "reuse", "dots", remat_last_micro=True)
assert np.array_equal(l_reu, l_rl)
assert_bitwise(g_reu, g_rl, "remat_last_micro-x-reuse")
print("bitwise OK")
print("LM ORACLE OK")
"""

LM_TRAIN_CURVE = COMMON + """
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.core.pipeline import TickCtx
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
key = jax.random.PRNGKey(0)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
m = 4
pcfg = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=m,
                      schedule="zb", residuals="reuse", remat="dots",
                      executor=EXECUTOR)
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = LMModel(arch, pcfg, dtype=jnp.float32)
params = model.init(key)
batch = {k: jax.random.randint(jax.random.fold_in(key, len(k)), v.shape, 0,
                               arch.vocab)
         for k, v in model.input_specs(shape).items()}
ocfg = optim.OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)

# pipeline side: the production train step (fused zb + residual reuse)
with set_mesh(mesh):
    step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))
    p_pipe, o_pipe = params, optim.init(ocfg, params)
    pipe_losses = []
    for _ in range(5):
        p_pipe, o_pipe, metrics = step(p_pipe, o_pipe, batch)
        pipe_losses.append(float(metrics["loss"]))

# oracle side: sequential stage chain + jax.grad + the same optimizer
stage_apply = model.make_stage_apply(model.consts())
def oracle_loss(p, b):
    fresh = model.embed_inputs(p["embed"], b)
    fresh_mb = jax.tree.map(
        lambda a: a.reshape((m, a.shape[0] // m) + a.shape[1:]), fresh)
    labels_mb = b["labels"].reshape(
        (m, b["labels"].shape[0] // m) + b["labels"].shape[1:])
    hp = {"head": p["head"], "embed": p["embed"]}
    total = jnp.zeros((), jnp.float32)
    for i in range(m):
        fresh_i = jax.tree.map(lambda a: a[i], fresh_mb)
        carry = {"h": jnp.zeros_like(fresh_i["h"])}
        for s in range(model.n_stages):
            ctx = TickCtx(stage=jnp.int32(s), micro=jnp.int32(i),
                          valid=jnp.asarray(True), t=jnp.int32(0),
                          fresh=fresh_i, n_stages=model.n_stages, n_micro=m)
            p_s = jax.tree.map(lambda a: a[s], p["stages"])
            carry, _, _ = stage_apply(p_s, carry, {}, {}, ctx)
        total = total + model.head_loss(hp, carry["h"],
                                        labels_mb[i]).astype(jnp.float32)
    return total / m

@jax.jit
def oracle_step(p, o, b):
    loss, grads = jax.value_and_grad(oracle_loss)(p, b)
    p2, o2, _ = optim.apply(ocfg, o, p, grads)
    return p2, o2, loss

p_o, o_o = params, optim.init(ocfg, params)
oracle_losses = []
for _ in range(5):
    p_o, o_o, loss = oracle_step(p_o, o_o, batch)
    oracle_losses.append(float(loss))

print("pipe  :", pipe_losses)
print("oracle:", oracle_losses)
np.testing.assert_allclose(pipe_losses, oracle_losses, rtol=2e-3, atol=1e-5)
assert pipe_losses[-1] < pipe_losses[0], "training must make progress"
print("TRAIN CURVE OK")
"""

UNET_ORACLE = COMMON + """
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.core import stage as stage_lib
from repro.launch import mesh as mesh_lib
from repro.models import pipeline_hetero as PH
from repro.models.unet import UNetConfig, UNetModel

key = jax.random.PRNGKey(0)
ucfg = UNetConfig(B=1, C=8, levels=3, img=16)
UB, pipe, m = 8, 2, 4
mb = UB // m
x = jax.random.normal(jax.random.fold_in(key, 1), (UB, ucfg.img, ucfg.img, 3))

MATRIX = [("gpipe_tasked", "recompute", "full"),
          ("1f1b", "recompute", "full"),
          ("interleaved:2", "recompute", "full"),
          ("zb", "recompute", "full"),
          ("zb", "reuse", "dots")]
results = {}
for schedule, residuals, remat in MATRIX:
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                          portals=True, remat=remat, schedule=schedule,
                          residuals=residuals, executor=EXECUTOR)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    umodel = UNetModel(ucfg, pipe * pcfg.virtual_stages)
    uparams = umodel.init(jax.random.PRNGKey(0))
    prog = PH.build_hetero_program(umodel, uparams, mb, pcfg, x[:2])
    tgt = jnp.zeros((UB,) + tuple(prog.out_proto.shape[1:]), jnp.float32)
    with set_mesh(mesh):
        call = jax.jit(PH.hetero_grad_call(prog, mesh, pcfg))
        loss, g_stage = call(prog.stacked_params, x, tgt)
    loss, g_stage = np.asarray(loss), np.asarray(g_stage)
    results[(schedule, residuals)] = (loss, g_stage)

    # oracle: direct layer chain (UNetModel.apply_sequential), jax.grad
    def oracle_loss(params_list):
        total = jnp.zeros((), jnp.float32)
        for i in range(m):
            xi = x[i * mb:(i + 1) * mb]
            yi = tgt[i * mb:(i + 1) * mb].reshape(mb, -1)
            out = umodel.apply_sequential(params_list, xi)
            total = total + jnp.mean((out.reshape(mb, -1) - yi) ** 2)
        return total / m
    o_loss, o_grads = jax.jit(jax.value_and_grad(oracle_loss))(uparams)
    np.testing.assert_allclose(np.asarray(o_loss), loss, rtol=2e-5)
    # fused grads are flat-packed per stage: flatten the oracle's the same
    # way and compare (the padding tail must be exactly zero)
    for s in range(umodel.n_stages):
        lo, hi = umodel.bounds[s], umodel.bounds[s + 1]
        flat, _, _ = stage_lib.flatten_params(
            jax.tree.map(np.asarray, o_grads[lo:hi]))
        got = g_stage[s]
        np.testing.assert_allclose(np.asarray(flat), got[:flat.shape[0]],
                                   rtol=5e-4, atol=5e-5,
                                   err_msg=f"{schedule} stage {s}")
        assert not got[flat.shape[0]:].any(), f"{schedule} stage {s} padding"
    print("unet oracle OK", schedule, residuals)

l_rec, g_rec = results[("zb", "recompute")]
l_reu, g_reu = results[("zb", "reuse")]
assert np.array_equal(l_rec, l_reu) and np.array_equal(g_rec, g_reu)
print("UNET ORACLE OK")
"""


def test_oracle_lm():
    """Every fused schedule (incl. zb residual reuse and recompute) matches
    a from-scratch single-device jax.grad reference on the LM model, and
    zb-reuse is bitwise against zb-recompute."""
    out = run_subprocess(LM_ORACLE.replace("__ARCH__", repr("smollm-360m")),
                         n_devices=8, timeout=2400)
    assert "LM ORACLE OK" in out


def test_oracle_whisper_portal():
    """The encoder-decoder portal model (skip routes through the plan)
    matches the sequential oracle under every fused schedule."""
    out = run_subprocess(LM_ORACLE.replace("__ARCH__", repr("whisper-tiny")),
                         n_devices=8, timeout=2400)
    assert "LM ORACLE OK" in out


def test_oracle_unet_hetero():
    """The heterogeneous (switch-program) U-Net matches jax.grad over
    UNetModel.apply_sequential under every fused schedule."""
    out = run_subprocess(UNET_ORACLE, n_devices=8, timeout=2400)
    assert "UNET ORACLE OK" in out


def test_oracle_train_curve():
    """5 optimizer steps of the fused zb+reuse train step track the oracle
    train loop's loss curve."""
    out = run_subprocess(LM_TRAIN_CURVE, n_devices=8, timeout=1800)
    assert "TRAIN CURVE OK" in out
