"""Heterogeneous (switch-stage) pipeline: U-Net and AmoebaNet-D equal their
sequential oracles through the pipeline, in both skip-routing modes."""
import pytest

from conftest import run_subprocess

UNET = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.unet import UNetConfig, UNetModel
from repro.models import pipeline_hetero as PH

cfg = UNetConfig(B=1, C=4, levels=3, img=32)
pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=2,
                      portals={portals}, remat="full")
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = UNetModel(cfg, pcfg.pipe)
params = model.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3))
prog = PH.build_hetero_program(model, params, 4, pcfg, x[:4])
if {portals}:
    assert prog.skips, "portal edges expected for cross-stage skips"
with set_mesh(mesh):
    y_pipe = jax.jit(lambda xx: PH.hetero_forward(prog, mesh, pcfg, xx))(x)
y_seq = model.apply_sequential(params, x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                           rtol=2e-4, atol=2e-4)
# gradients flow through the switch program + portals
with set_mesh(mesh):
    def loss(p, xx):
        prog2 = PH.HeteroProgram(p, prog.stage_apply, prog.carry_proto,
                                 prog.skips, prog.skip_protos, prog.out_proto)
        return jnp.mean(PH.hetero_forward(prog2, mesh, pcfg, xx) ** 2)
    g = jax.jit(jax.grad(loss))(prog.stacked_params, x)
assert bool(jnp.isfinite(g).all())
print("UNET HETERO OK portals={portals}")
"""

AMOEBA = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.amoebanet import AmoebaConfig, AmoebaNetModel
from repro.models import pipeline_hetero as PH

cfg = AmoebaConfig(L=6, F=16, img=32, n_classes=10)
pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=2)
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = AmoebaNetModel(cfg, pcfg.pipe)
params = model.init(jax.random.PRNGKey(2))
x = jax.random.normal(jax.random.PRNGKey(3), (8, 32, 32, 3))
prog = PH.build_hetero_program(model, params, 4, pcfg, x[:4])
with set_mesh(mesh):
    y_pipe = jax.jit(lambda xx: PH.hetero_forward(prog, mesh, pcfg, xx))(x)
y_seq = model.apply_sequential(params, x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                           rtol=2e-4, atol=2e-4)
print("AMOEBANET HETERO OK")
"""


@pytest.mark.parametrize("portals", [True, False])
def test_unet_pipeline_equals_sequential(portals):
    run_subprocess(UNET.format(portals=portals), n_devices=8, timeout=900)


def test_amoebanet_pipeline_equals_sequential():
    run_subprocess(AMOEBA, n_devices=8, timeout=900)


def test_unet_balance_and_edges():
    """Partition + portal-edge derivation are stable host-side properties."""
    from repro.models.unet import UNetConfig, UNetModel
    model = UNetModel(UNetConfig(B=2, C=8, levels=4, img=64), 4)
    assert sum(model.sizes) == len(model.layers)
    edges = model.skip_edges()
    for e in edges:
        assert all(d > e.src_stage for d in e.dsts)
    # deeper B -> more layers, same stage count
    model2 = UNetModel(UNetConfig(B=4, C=8, levels=4, img=64), 4)
    assert len(model2.layers) > len(model.layers)
    assert len(model2.sizes) == 4


def test_batchnorm_caveat_discrepancy():
    """Paper §2 fn 1: BatchNorm statistics differ under micro-batching;
    GroupNorm (our default) is micro-batch invariant."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.unet import UNetConfig, UNetModel
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 16, 3))
    for norm, should_match in (("group", True), ("batch", False)):
        cfg = UNetConfig(B=1, C=4, levels=2, img=16, norm=norm)
        model = UNetModel(cfg, 1)
        params = model.init(jax.random.PRNGKey(1))
        full = model.apply_sequential(params, x)
        halves = jnp.concatenate([model.apply_sequential(params, x[:4]),
                                  model.apply_sequential(params, x[4:])])
        match = bool(jnp.allclose(full, halves, rtol=1e-4, atol=1e-4))
        assert match == should_match, (norm, match)
