"""Residual-reuse plan properties (ZB-H1, ``residuals="reuse"``).

Hypothesis suites prove, for random (m, n, v) tables, that the executed
plan's high-water park + residual slot usage — traced tick by tick from
the plan's own event arrays — exactly equals the schedule-level
predictions (``schedules.peak_park`` / ``schedules.peak_residuals``), and
that malformed reuse tables (a Bw before its Bx, a double-freeing second
Bw) are rejected.  Edge-case schedules (m < n, m = 1, stages that don't
tile the rank count) and the parse-time config validation ride along.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ParallelConfig
from repro.core import plan as PL
from repro.core import schedules as S
from repro.core.plan import _alloc_intervals
from repro.core.schedules import Task

mn = st.tuples(st.integers(1, 16), st.integers(1, 8))
wnv = st.tuples(st.integers(1, 3), st.integers(1, 5), st.integers(2, 3))


def traced_highwater(write, read, rank):
    """Max concurrently-occupied slots on ``rank``, replayed from the plan
    arrays: a slot goes live at its write tick and stays live through its
    last read before the next write of the same slot."""
    T = write.shape[0]
    open_t, last_rd, intervals = {}, {}, []
    for t in range(T):
        w, r = int(write[t, rank]), int(read[t, rank])
        if w >= 0:
            if w in open_t:          # slot recycled: close the old residency
                intervals.append((open_t[w], last_rd[w]))
            open_t[w] = t
            last_rd[w] = t
        if r >= 0:
            assert r in open_t, f"tick {t}: read of never-written slot {r}"
            last_rd[r] = t
    intervals += [(t0, last_rd[s]) for s, t0 in open_t.items()]
    # closed-interval max overlap == the free-list allocator's high-water
    events = sorted([(a, 1) for a, _ in intervals]
                    + [(c + 1, -1) for _, c in intervals])
    live = peak = 0
    for _, d in events:
        live += d
        peak = max(peak, live)
    return peak


@given(mn)
@settings(max_examples=40, deadline=None)
def test_zb_reuse_slot_highwater_matches_prediction(m_n):
    """For every random (m, n): the reuse plan's traced park AND residual
    slot high-water equals peak_park / peak_residuals exactly, per rank."""
    m, n = m_n
    table = S.zb_schedule(m, n)
    plan = PL.lower_tasks(table, m, n, residuals="reuse")
    assert plan.residuals == "reuse"
    pred_park = S.peak_park(table, n)
    pred_resid = S.peak_residuals(table, n)
    assert list(plan.per_stage_park) == pred_park
    assert list(plan.per_stage_resid) == pred_resid
    assert plan.resid_depth == max(pred_resid)
    for r in range(n):
        assert traced_highwater(plan.park_recv, plan.park_read, r) \
            == pred_park[r], ("park", m, n, r)
        assert traced_highwater(plan.resid_write, plan.resid_read, r) \
            == pred_resid[r], ("resid", m, n, r)
    # every Bx writes a residual slot and its Bw reads the same slot
    for r in range(n):
        by_micro = {}
        for t in range(plan.n_ticks):
            if plan.kind[t, r] == PL.BWD_X:
                assert plan.resid_write[t, r] >= 0
                by_micro[int(plan.micro[t, r])] = int(plan.resid_write[t, r])
            if plan.kind[t, r] == PL.BWD_W:
                assert int(plan.resid_read[t, r]) \
                    == by_micro[int(plan.micro[t, r])]


@given(mn)
@settings(max_examples=30, deadline=None)
def test_park_highwater_matches_prediction_fused(m_n):
    """peak_park predicts the donated park high-water for the fused tables
    too (gpipe / 1f1b), traced from the plan's own arrays."""
    m, n = m_n
    for table in (S.gpipe_schedule(m, n, checkpoint=False),
                  S.one_f_one_b_schedule(m, n)):
        plan = PL.lower_tasks(table, m, n)
        pred = S.peak_park(
            [tick for tick in table if any(t.kind != "R" for t in tick)], n)
        assert list(plan.per_stage_park) == pred
        for r in range(n):
            assert traced_highwater(plan.park_recv, plan.park_read, r) \
                == pred[r]


@given(wnv)
@settings(max_examples=25, deadline=None)
def test_interleaved_park_prediction_chunked(wnv_):
    """Chunked tables aggregate co-resident stages into per-RANK peaks;
    the prediction stays exact."""
    w, n, v = wnv_
    m = w * n
    table = S.interleaved_1f1b_schedule(m, n, v)
    plan = PL.lower_tasks(table, m, n * v, ranks=n)
    pred = S.peak_park(table, n * v, ranks=n)
    assert list(plan.per_stage_park) == pred
    for r in range(n):
        assert traced_highwater(plan.park_recv, plan.park_read, r) == pred[r]


# ---------------------------------------------------------------------------
# Reject paths: malformed reuse tables
# ---------------------------------------------------------------------------

def test_reject_bw_before_bx():
    """A Bw scheduled before its Bx is rejected (validate's split-backward
    ordering check runs inside lower_tasks)."""
    # hoist Bw[0,1] to tick 0, ahead of its Bx
    moved = Task("Bw", 0, 1)
    table = [[t for t in tick if t != moved]
             for tick in S.zb_schedule(4, 2)]
    table[0].append(moved)
    with pytest.raises(AssertionError, match="Bx"):
        S.validate(table, 4, 2, backward_micro_order=False)
    with pytest.raises(AssertionError):
        PL.lower_tasks(table, 4, 2, residuals="reuse")


def test_reject_double_free():
    """A second Bw for the same (micro, stage) — a double free of the
    residual slot — is rejected as a duplicate task."""
    table = [list(tick) for tick in S.zb_schedule(4, 2)]
    table.append([Task("Bw", 0, 0)])
    with pytest.raises(AssertionError, match="duplicate"):
        S.validate(table, 4, 2, backward_micro_order=False)
    with pytest.raises(AssertionError):
        PL.lower_tasks(table, 4, 2, residuals="reuse")


def test_reject_bw_without_bx():
    """peak_residuals refuses a Bw with no matching Bx."""
    table = [[Task("F", 0, 0)], [Task("Bw", 0, 0)]]
    with pytest.raises(ValueError, match="no matching Bx"):
        S.peak_residuals(table, 1)


def test_reject_interval_arriving_after_last_use():
    """The slot allocator itself refuses inverted intervals (the second
    line of defense under a validate bypass)."""
    with pytest.raises(AssertionError, match="arrives"):
        _alloc_intervals([[(5, 3, "x")]])


def test_reject_unknown_residuals_mode():
    with pytest.raises(ValueError, match="residuals"):
        PL.lower_tasks(S.zb_schedule(2, 2), 2, 2, residuals="cached")


# ---------------------------------------------------------------------------
# Edge-case schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 1), (1, 4), (2, 4), (3, 5)])
def test_zb_reuse_edge_shapes(m, n):
    """m < n and m = 1: the reuse plan still covers every task, pairs each
    Bx with a residual slot, and the predictions hold."""
    table = S.zb_schedule(m, n)
    plan = PL.lower_tasks(table, m, n, residuals="reuse")
    n_bx = int((plan.kind == PL.BWD_X).sum())
    n_bw = int((plan.kind == PL.BWD_W).sum())
    assert n_bx == n_bw == m * n
    assert int((plan.resid_write >= 0).sum()) == m * n
    assert int((plan.resid_read >= 0).sum()) == m * n
    assert list(plan.per_stage_resid) == S.peak_residuals(table, n)
    # with one micro-batch at most one residual is ever live per rank
    if m == 1:
        assert plan.resid_depth == 1


def test_stages_must_tile_ranks():
    """A v that doesn't divide the stage count onto the ranks is a clear
    ValueError at lowering, not a deep executor failure."""
    table = S.one_f_one_b_schedule(4, 6)
    with pytest.raises(ValueError, match="tile"):
        PL.lower_tasks(table, 4, 6, ranks=4)
    with pytest.raises(ValueError, match="divisible"):
        S.interleaved_1f1b_schedule(6, 4, 2)     # m % n != 0


def test_reuse_coerces_on_fused_tables():
    """residuals="reuse" on a fused-backward schedule has nothing to reuse
    across ticks: the plan coerces to recompute with zero residual slots."""
    for schedule in ("gpipe_tasked", "1f1b", "interleaved:2"):
        p = PL.plan_for(schedule, 4, 2, residuals="reuse")
        assert p.residuals == "recompute"
        assert p.resid_depth == 0
        assert (p.resid_write == -1).all() and (p.resid_read == -1).all()
    fwd = PL.plan_for("gpipe_fwd", 4, 2)
    assert fwd.residuals == "recompute"


# ---------------------------------------------------------------------------
# Cost model + config validation
# ---------------------------------------------------------------------------

def test_reuse_cost_model_prices_bw_cheaper():
    """Under reuse pricing Bw = 1 forward (no second remat): the zb
    dedicated-device critical path strictly undercuts both recompute-zb and
    plain 1F1B whenever there is real pipelining."""
    for m, n in [(4, 4), (8, 4), (8, 2), (2, 4)]:
        table = S.zb_schedule(m, n)
        t_rec, _ = S.simulate_device_times(
            table, n, S.default_task_cost(n, n, residuals="recompute"))
        t_reu, _ = S.simulate_device_times(
            table, n, S.default_task_cost(n, n, residuals="reuse"))
        assert t_reu < t_rec, (m, n)
        t_f1b, _ = S.simulate_device_times(S.one_f_one_b_schedule(m, n), n)
        if n > 1:
            assert t_reu < t_f1b, (m, n)
    # remat="full" + reuse has an empty stash and still recomputes: the
    # cost model must price it as recompute, never promising a payoff the
    # executor cannot deliver
    table = S.zb_schedule(8, 4)
    t_rec, _ = S.simulate_device_times(
        table, 4, S.default_task_cost(4, 4, residuals="recompute"))
    t_degenerate, _ = S.simulate_device_times(
        table, 4, S.default_task_cost(4, 4, residuals="reuse", remat="full"))
    assert t_degenerate == t_rec
    # schedule_bubble is residuals- and remat-aware (the dry-run term)
    assert PL.schedule_bubble("zb", 8, 4, residuals="reuse") \
        != PL.schedule_bubble("zb", 8, 4, residuals="recompute")
    assert PL.schedule_bubble("zb", 8, 4, residuals="reuse", remat="full") \
        == PL.schedule_bubble("zb", 8, 4, residuals="recompute")
    assert PL.schedule_bubble("zb", 8, 1, residuals="reuse") == 0.0


def test_config_validates_at_parse_time():
    """Typo'd remat / residuals values fail when the config is BUILT
    (satellite: no more failing deep inside wrap_stage)."""
    with pytest.raises(ValueError, match="remat"):
        ParallelConfig(remat="fulll")
    with pytest.raises(ValueError, match="residuals"):
        ParallelConfig(residuals="reuse_maybe")
    with pytest.raises(ValueError, match="virtual"):
        ParallelConfig(schedule="interleaved:0")
    with pytest.raises(ValueError, match="executor"):
        ParallelConfig(executor="simd")
    # the valid cross-product constructs
    for remat in ("none", "full", "dots", "dots_no_batch"):
        for residuals in ("recompute", "reuse"):
            for executor in ("spmd", "mpmd"):
                cfg = ParallelConfig(remat=remat, residuals=residuals,
                                     executor=executor)
                assert cfg.remat == remat and cfg.residuals == residuals
                assert cfg.executor == executor


def test_zb_recompute_advisory():
    """The perf gate (satellite): zb + recompute carries an advisory
    recommending residuals="reuse"; zb + reuse and every other schedule
    are clean."""
    assert any("reuse" in a
               for a in ParallelConfig(schedule="zb").advisories())
    assert ParallelConfig(schedule="zb", residuals="reuse",
                          remat="dots").advisories() == ()
    assert ParallelConfig(schedule="1f1b").advisories() == ()


def test_policies_match_checkpointing():
    """configs.base.REMAT_POLICIES is the same tuple checkpointing.POLICIES
    exposes (the comment-drift satellite, now enforced)."""
    from repro.configs.base import REMAT_POLICIES, RESIDUAL_MODES
    from repro.core import checkpointing
    assert checkpointing.POLICIES == REMAT_POLICIES
    assert RESIDUAL_MODES == ("recompute", "reuse")
    with pytest.raises(ValueError):
        checkpointing.wrap_stage(lambda x: x, "bogus")
    with pytest.raises(ValueError):
        checkpointing.wrap_for_residuals(lambda x: x, "full", "bogus")


def test_kind_arrays_zb_reuse_vs_recompute_identical():
    """Reuse changes WHAT backward ticks do, never WHEN: the task grid
    (kind/micro/chunk), park and b-inbox events are identical to the
    recompute plan's — only the residual events are added."""
    a = PL.plan_for("zb", 8, 4)
    b = PL.plan_for("zb", 8, 4, residuals="reuse")
    for field in ("kind", "micro", "chunk", "park_recv", "park_read",
                  "b_recv", "b_read"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), field
    assert a.segments == b.segments
    assert (a.resid_write == -1).all() and (b.resid_write >= 0).sum() == 32
