"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus gradient checks for the blocked VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6 import wkv6_pallas


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention kernel sweeps
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # B, Hq, Hkv, Sq, Sk, D
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 96, 96, 64),      # GQA, non-multiple-of-block seq
    (1, 8, 1, 128, 128, 32),    # MQA
    (2, 3, 3, 160, 160, 16),    # odd heads
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention_vs_oracle(shape, dtype, causal, window):
    B, Hq, Hkv, Sq, Sk, D = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Hq, Sq, D), dtype)
    k = rand(ks[1], (B, Hkv, Sk, D), dtype)
    v = rand(ks[2], (B, Hkv, Sk, D), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = ref.mha_naive(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_q_offset_decodes_prefill_chunk():
    """q_offset positions a later query chunk against the full key prefix."""
    B, H, S, D = 1, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (B, H, S, D))
    k = rand(ks[1], (B, H, S, D))
    v = rand(ks[2], (B, H, S, D))
    full = ref.mha_naive(q, k, v, causal=True)
    half = flash_attention(q[:, :, 64:], k, v, causal=True, q_offset=64,
                           block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(half), np.asarray(full[:, :, 64:]),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([32, 48, 96]),
       st.sampled_from([16, 32]))
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(b, h, s, d):
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + h * 10 + s + d), 3)
    q = rand(ks[0], (b, h, s, d))
    k = rand(ks[1], (b, h, s, d))
    v = rand(ks[2], (b, h, s, d))
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    want = ref.mha_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Blocked-jnp attention: custom VJP correctness (the XLA fallback path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(causal=True), dict(causal=False), dict(causal=True, window=24),
    dict(causal=True, kv_len=40),
])
def test_blocked_attention_grads_match_naive(kw):
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q, k, v = (rand(ks[i], (B, Hq if i == 0 else Hkv, S, D))
               for i in range(3))
    g = rand(ks[3], (B, Hq, S, D))

    def naive(q, k, v):
        kv_len = kw.get("kv_len")
        out = ref.mha_naive(q, k, v, causal=kw.get("causal", True),
                            window=kw.get("window", 0) or 0)
        if kv_len is not None:
            out = ref.mha_naive(
                q, k[:, :, :kv_len], v[:, :, :kv_len],
                causal=kw.get("causal", True), window=0)
        return out

    f_b = lambda *a: (ref.mha_blocked(*a, block_k=16, **kw)
                      .astype(jnp.float32) * g).sum()
    f_n = lambda *a: (naive(*a).astype(jnp.float32) * g).sum()
    gb = jax.grad(f_b, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_n, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gb, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_blocked_attention_traced_mask_params():
    """window/causal as traced scalars (mixed per-layer layouts)."""
    B, H, S, D = 1, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(ks[i], (B, H, S, D)) for i in range(3))

    @jax.jit
    def f(w):
        return ref.mha_blocked(q, k, v, causal=True, window=w, block_k=16)

    np.testing.assert_allclose(
        np.asarray(f(jnp.asarray(24))),
        np.asarray(ref.mha_naive(q, k, v, causal=True, window=24)),
        rtol=2e-5, atol=2e-5)
    # window = S  => equals unwindowed
    np.testing.assert_allclose(
        np.asarray(f(jnp.asarray(S))),
        np.asarray(ref.mha_naive(q, k, v, causal=True)),
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RWKV-6 WKV kernel
# ---------------------------------------------------------------------------

WKV_SHAPES = [
    # B, H, T, K, V, chunk
    (1, 1, 64, 8, 8, 16),
    (2, 3, 128, 16, 16, 32),
    (1, 2, 96, 32, 32, 32),
]


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_vs_oracle(shape, dtype):
    B, H, T, K, V, C = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = rand(ks[0], (B, H, T, K), dtype, 0.5)
    k = rand(ks[1], (B, H, T, K), dtype, 0.5)
    v = rand(ks[2], (B, H, T, V), dtype, 0.5)
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, H, T, K), jnp.float32, 0.5))).astype(dtype)
    u = rand(ks[4], (H, K), jnp.float32, 0.5)
    s0 = rand(ks[5], (B, H, K, V), jnp.float32, 0.3)
    got_o, got_s = wkv6_pallas(r, k, v, w, u, s0, chunk=C, interpret=True)
    want_o, want_s = ref.wkv6(r, k, v, w, u, s0)
    tol = 5e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got_o, np.float32),
                               np.asarray(want_o, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=tol, atol=tol)


def test_wkv6_chunked_ref_matches_sequential():
    B, H, T, K, V = 2, 2, 128, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k = rand(ks[0], (B, H, T, K), scale=0.5), rand(ks[1], (B, H, T, K), scale=0.5)
    v = rand(ks[2], (B, H, T, V), scale=0.5)
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, H, T, K), scale=0.5)))
    u = rand(ks[4], (H, K), scale=0.5)
    o1, s1 = ref.wkv6(r, k, v, w, u)
    o2, s2 = ref.wkv6_chunked(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_wkv6_state_chaining():
    """Processing [0:T/2] then [T/2:T] with carried state == full pass."""
    B, H, T, K, V = 1, 2, 64, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r, k = rand(ks[0], (B, H, T, K), scale=0.5), rand(ks[1], (B, H, T, K), scale=0.5)
    v = rand(ks[2], (B, H, T, V), scale=0.5)
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, H, T, K), scale=0.5)))
    u = rand(ks[4], (H, K), scale=0.5)
    o_full, s_full = ref.wkv6(r, k, v, w, u)
    h = T // 2
    o1, s1 = wkv6_pallas(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h],
                         u, chunk=16, interpret=True)
    o2, s2 = wkv6_pallas(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:],
                         u, s1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(o_full), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# decode_attend + LSE combine (sequence-sharded long-context decode)
# ---------------------------------------------------------------------------

def test_decode_attend_matches_full_softmax():
    B, H, S, D = 2, 3, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (B, H, 1, D))
    kc = rand(ks[1], (B, H, S, D))
    vc = rand(ks[2], (B, H, S, D))
    ln = jnp.full((B,), S, jnp.int32)
    out, _ = ref.decode_attend(q, kc, vc, ln)
    want = ref.mha_naive(q, kc, vc, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_lse_combine_equals_unsharded():
    """Partial (num, max, den) triples over sequence shards combine exactly."""
    B, H, S, D = 1, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = rand(ks[0], (B, H, 1, D))
    kc = rand(ks[1], (B, H, S, D))
    vc = rand(ks[2], (B, H, S, D))
    ln = jnp.full((B,), S, jnp.int32)
    full, _ = ref.decode_attend(q, kc, vc, ln)
    parts = []
    for sh in range(4):
        ksh = kc[:, :, sh * 16:(sh + 1) * 16]
        vsh = vc[:, :, sh * 16:(sh + 1) * 16]
        _, part = ref.decode_attend(q, ksh, vsh, jnp.full((B,), 16, jnp.int32))
        parts.append(part)
    combined = ref.lse_combine(parts)
    np.testing.assert_allclose(np.asarray(combined, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_rmsnorm():
    x = rand(jax.random.PRNGKey(6), (4, 32), jnp.bfloat16)
    s = jnp.ones((32,), jnp.bfloat16) * 2
    got = ref.rmsnorm(x, s)
    x32 = np.asarray(x, np.float32)
    want = x32 / np.sqrt((x32 ** 2).mean(-1, keepdims=True) + 1e-6) * 2
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Fused RMSNorm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 7, 64), (130, 96), (1, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_vs_oracle(shape, dtype):
    from repro.kernels.rmsnorm import rmsnorm_pallas
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 2).astype(dtype)
    s = (jax.random.normal(jax.random.PRNGKey(1), shape[-1:]) + 1).astype(dtype)
    got = rmsnorm_pallas(x, s, block_rows=32, interpret=True)
    want = ref.rmsnorm(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
