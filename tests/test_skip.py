"""Portal mechanics (paper §3.3.1): ring timing, multi-destination edges."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.skip import SkipSpec, ring_init, ring_push, ring_read


def test_skipspec_validation():
    with pytest.raises(ValueError):
        SkipSpec("bad", 3, (2,))
    with pytest.raises(ValueError):
        SkipSpec("empty", 0, ())
    s = SkipSpec("ok", 1, (3, 5))
    assert s.depth(3) == 2 and s.depth(5) == 4


def test_ring_delivery_timing():
    """A value pushed at the end of tick τ must be read at dst exactly at
    tick τ + (dst - src): src produces for micro-batch i at tick i+src, dst
    consumes at tick i+dst."""
    spec = SkipSpec("mem", src_stage=1, dsts=(4,))
    proto = jnp.zeros((2,))
    rings = ring_init(spec, proto)
    assert rings[4].shape == (3, 2)   # depth = dst - src

    payloads = [jnp.full((2,), float(t + 1)) for t in range(8)]
    ring = rings[4]
    reads = []
    for t in range(8):
        reads.append(float(ring_read(spec, 4, ring)[0]))
        ring = ring_push(ring, payloads[t])
    # value sent at tick τ (payload τ+1) is read at tick τ + depth
    depth = spec.depth(4)
    for tau in range(8 - depth):
        assert reads[tau + depth] == float(tau + 1)


def test_ring_depth_one():
    spec = SkipSpec("adj", 2, (3,))
    ring = ring_init(spec, jnp.zeros((1,)))[3]
    assert ring.shape == (1, 1)
    ring = ring_push(ring, jnp.ones((1,)))
    assert float(ring_read(spec, 3, ring)[0]) == 1.0


def test_multi_destination_rings_independent():
    spec = SkipSpec("mem", 0, (1, 3))
    rings = ring_init(spec, jnp.zeros(()))
    r1 = ring_push(rings[1], jnp.asarray(5.0))
    r3 = rings[3]
    for _ in range(3):
        r3 = ring_push(r3, jnp.asarray(7.0))
    assert float(ring_read(spec, 1, r1)) == 5.0
    assert float(ring_read(spec, 3, r3)) == 7.0
    assert rings[1].shape[0] == 1 and rings[3].shape[0] == 3
