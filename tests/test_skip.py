"""Portal mechanics (paper §3.3): skip edges lower to plan routes.

Since the runtime unification there is no separate ring machinery — a
``SkipSpec`` edge lowers (``repro.core.plan._lower_routes`` via
``lower_tasks``) to a static per-(edge, destination) transfer schedule the
single executor runs.  These tests prove the lowering host-side, with no
devices: delivery timing, buffer depths against ``SkipSpec.depth``, the
F->B hold that the fused backward's recompute relies on, and
multi-destination independence.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import plan as PL
from repro.core.skip import SkipSpec


def test_skipspec_validation():
    with pytest.raises(ValueError):
        SkipSpec("bad", 3, (2,))
    with pytest.raises(ValueError):
        SkipSpec("empty", 0, ())
    s = SkipSpec("ok", 1, (3, 5))
    assert s.depth(3) == 2 and s.depth(5) == 4


def simulate_route(plan: PL.TaskPlan, rt: PL.RoutePlan):
    """Host-side replay of one route's forward flow.

    Returns ``{(tick, rank): micro}`` for every buffer read, by walking the
    plan arrays exactly as the executor does: producers transmit their
    task's micro, hops move tagged values along ``fwd_perm``, arrivals park
    in slots, reads consume parked slots.
    """
    n = plan.n_stages
    buf = {}                      # (rank, slot) -> micro tag
    fly = {}                      # rank -> micro tag in flight
    reads = {}
    for t in range(plan.n_ticks):
        # 1. park arrivals
        for j in range(n):
            if rt.recv[t, j] >= 0:
                assert j in fly, f"tick {t}: rank {j} parks nothing"
                buf[(j, int(rt.recv[t, j]))] = fly[j]
        # 2. reads
        for j in range(n):
            if rt.read[t, j] >= 0:
                key = (j, int(rt.read[t, j]))
                assert key in buf, f"tick {t}: rank {j} reads empty slot"
                reads[(t, j)] = buf[key]
        # 3. sends -> hop
        sent = {}
        for j in range(n):
            s = int(rt.send[t, j])
            if s == PL.SEND_STAGE:
                assert plan.kind[t, j] == PL.FWD, "producer send off-task"
                sent[j] = int(plan.micro[t, j])
            elif s >= 0:
                sent[j] = buf[(j, s)]
        fly = {b: sent[a] for a, b in rt.fwd_perm if a in sent}
    return reads


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(2, 6), st.integers(0, 4),
       st.integers(1, 5), st.booleans())
def test_route_lowering_preserves_depth_invariants(m, n, src, span, portals):
    """Property (satellite): for any edge and schedule family, the lowered
    route's forward buffer never exceeds ``SkipSpec.depth`` live values on
    the wavefront plan, hops cover exactly the ``depth(dst)`` links in
    threaded mode (one direct pair in portal mode), and every consuming
    read at ``F(i, dst)`` observes the value produced at ``F(i, src)``."""
    dst = src + span
    if dst >= n:
        dst = n - 1
        if dst <= src:
            src = dst - 1
            if src < 0:
                return
    spec = SkipSpec("s", src, (dst,))
    plan = PL.plan_for("gpipe_fwd", m, n, skips=[spec], portals=portals)
    (rt,) = plan.routes
    # depth bound: the legacy ring allocated exactly depth(dst); the route
    # allocator is at least as tight (fewer when m is small).
    if portals:
        assert rt.fwd_perm == ((src, dst),)
        assert rt.depth == min(spec.depth(dst), m)
    else:
        assert len(rt.fwd_perm) == spec.depth(dst)
        assert rt.fwd_perm == tuple((j, j + 1) for j in range(src, dst))
        assert rt.depth == 1          # wavefront: relay in, relay out
    reads = simulate_route(plan, rt)
    # delivery: consumed at F(i, dst)'s tick with the matching micro
    f_ticks = {(int(plan.micro[t, dst]), t)
               for t in range(plan.n_ticks) if plan.kind[t, dst] == PL.FWD}
    assert {(mi, t) for (t, j), mi in reads.items() if j == dst} == f_ticks


@pytest.mark.parametrize("schedule", ["gpipe", "1f1b"])
@pytest.mark.parametrize("m,n,src,dst", [(4, 4, 0, 3), (8, 4, 1, 3),
                                         (6, 3, 0, 2)])
def test_fb_route_holds_value_until_backward(schedule, m, n, src, dst):
    """F+B plans must keep the portal value parked from the consumer's
    forward until its backward recompute (what autodiff kept alive as a
    checkpoint residual in the legacy loop)."""
    spec = SkipSpec("s", src, (dst,))
    plan = PL.plan_for(schedule, m, n, skips=[spec], portals=True)
    (rt,) = plan.routes
    reads = simulate_route(plan, rt)
    # every micro is read exactly twice at dst: once at F, once at B
    per_micro = {}
    for (t, j), mi in reads.items():
        assert j == dst
        per_micro.setdefault(mi, []).append((t, int(plan.kind[t, j])))
    for i in range(m):
        kinds = sorted(k for _, k in per_micro[i])
        assert kinds == [PL.FWD, PL.BWD], (i, per_micro[i])
    # and the cotangent route mirrors it: one g_send at B(i, dst), one
    # g_read (VJP seed) at B(i, src)
    for i in range(m):
        tb_dst = [t for t in range(plan.n_ticks)
                  if plan.kind[t, dst] == PL.BWD and plan.micro[t, dst] == i]
        tb_src = [t for t in range(plan.n_ticks)
                  if plan.kind[t, src] == PL.BWD and plan.micro[t, src] == i]
        assert rt.g_send[tb_dst[0], dst] == PL.SEND_STAGE
        assert rt.g_read[tb_src[0], src] >= 0
        assert tb_dst[0] < tb_src[0]


def test_multi_destination_routes_independent():
    """One route per destination, each with its own buffer and timing —
    the whisper encoder-memory pattern (src -> every decoder stage)."""
    spec = SkipSpec("mem", 0, (1, 3))
    plan = PL.plan_for("gpipe_fwd", 4, 4, skips=[spec], portals=True)
    assert [rt.key for rt in plan.routes] == ["mem@1", "mem@3"]
    d1, d3 = plan.routes
    assert d1.depth == min(1, 4) and d3.depth == min(3, 4)
    r1 = simulate_route(plan, d1)
    r3 = simulate_route(plan, d3)
    assert {j for (_, j) in r1} == {1}
    assert {j for (_, j) in r3} == {3}
    assert len(r1) == len(r3) == 4          # every micro delivered once


def test_threaded_route_relays_through_intermediates():
    """Threaded mode (the §3.3 symptomatic case): every intermediate rank
    re-sends the value on its own F tick — the per-hop traffic the portal
    ablation benchmark measures."""
    spec = SkipSpec("s", 0, (3,))
    plan = PL.plan_for("gpipe_fwd", 4, 4, skips=[spec], portals=False)
    (rt,) = plan.routes
    for j in (1, 2):              # relays forward on their own F ticks
        relay_ticks = [t for t in range(plan.n_ticks) if rt.send[t, j] >= 0]
        f_ticks = [t for t in range(plan.n_ticks)
                   if plan.kind[t, j] == PL.FWD]
        assert relay_ticks == f_ticks
    reads = simulate_route(plan, rt)
    assert len(reads) == 4 and {j for (_, j) in reads} == {3}
