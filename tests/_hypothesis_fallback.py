"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests use a small, stable slice of the hypothesis API
(``given`` / ``settings`` / ``strategies.{integers,floats,booleans,lists,
tuples,sampled_from}`` plus ``.map``).  CI installs the real package (see
pyproject.toml); this fallback keeps the tier-1 suite runnable in hermetic
containers that cannot pip-install, by replaying each strategy with a
deterministic per-test PRNG.  No shrinking, no database — a failing example
is reported verbatim by pytest.

Activated by ``conftest.py`` only when ``import hypothesis`` fails.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rng: random.Random):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 1000 examples")
        return SearchStrategy(draw)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    SearchStrategy = SearchStrategy

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> SearchStrategy:
        options = list(options)
        return SearchStrategy(lambda rng: rng.choice(options))

    @staticmethod
    def lists(elem: SearchStrategy, *, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(rng: random.Random):
            k = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(k)]
        return SearchStrategy(draw)

    @staticmethod
    def tuples(*elems: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(e.example(rng) for e in elems))


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES,
             deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: SearchStrategy):
    def deco(fn):
        n = getattr(fn, "_fallback_max_examples", DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # Deterministic per-test stream: boundary-ish first example
            # ordering is not replicated, but seeds are stable run-to-run.
            rng = random.Random(f"fallback:{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strats)
                fn(*args, *drawn, **kwargs)

        # pytest must not mistake the strategy-filled parameters for
        # fixtures: hide the wrapped signature entirely.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def assume(condition: bool) -> bool:
    # Real hypothesis aborts the example; without shrinking machinery we can
    # only skip by returning early — callers in this repo don't use assume.
    if not condition:
        raise NotImplementedError("assume() unsupported in fallback")
    return True
