"""Shared test utilities.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
(assignment requirement).  Multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves (see ``run_subprocess``).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

try:
    import hypothesis  # noqa: F401  (preferred when installed — see pyproject)
except ImportError:
    # Hermetic containers can't pip-install; register the deterministic
    # fallback under the real name so test modules import it unchanged.
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run ``code`` in a fresh python with N host devices; assert rc == 0."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import sys
        sys.path.insert(0, {REPO_SRC!r})
    """)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def tmp_ckpt_dir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("ckpt"))
