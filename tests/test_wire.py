"""Wire engineering (PR 7): codec round-trips, latch invariants, parity.

Host-side property suites cover the codec layer itself (`WireSpec`
parsing/accounting, the `_Codec` encode-at-latch / decode-at-arrival
kernels, the EF-SGD residual algebra) and the plan-level latch invariant
(`plan.assert_route_overlap`: every route arrival has a one-tick-earlier
latch on the producing rank, the property the mpmd double buffering
relies on).  Subprocess tests run the real multi-device executor: every
wire mode must be bitwise-identical across spmd/mpmd, and the lossy
int8-ef mode must pass the single-device oracle to stated tolerances
plus a 5-step loss-curve check.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import run_subprocess

from repro.core import plan as plan_lib
from repro.core import wire as wire_lib
from repro.core.wire import WireSpec


# ---------------------------------------------------------------------------
# WireSpec: parse / round-trip / byte accounting (numpy-only, no devices)
# ---------------------------------------------------------------------------

def test_wirespec_parse_and_roundtrip():
    for s in ("fp32", "bf16", "int8-ef"):
        w = WireSpec.parse(s)
        assert w.chain == w.portal == w.cotangent == s
        assert w.name == s
        assert WireSpec.parse(w.name) == w
    mixed = WireSpec.parse("chain=bf16,portal=fp32,cotangent=int8-ef")
    assert (mixed.chain, mixed.portal, mixed.cotangent) == \
        ("bf16", "fp32", "int8-ef")
    assert WireSpec.parse(mixed.name) == mixed
    assert WireSpec.from_dict(mixed.to_dict()) == mixed
    # parse is idempotent on specs and tolerant of None/empty
    assert WireSpec.parse(mixed) is mixed
    assert WireSpec.parse(None) == wire_lib.WIRE_FP32
    assert WireSpec.parse("") == wire_lib.WIRE_FP32

    assert wire_lib.WIRE_FP32.lossless and not wire_lib.WIRE_FP32.stateful
    assert not mixed.lossless and mixed.stateful
    assert not WireSpec.parse("bf16").lossless
    assert not WireSpec.parse("bf16").stateful

    with pytest.raises(ValueError):
        WireSpec.parse("fp16")
    with pytest.raises(ValueError):
        WireSpec.parse("chain=bf16,carry=fp32")
    with pytest.raises(ValueError):
        WireSpec(block=0)


def test_bytes_factor_and_hop_units():
    assert wire_lib.bytes_factor("fp32") == 1.0
    assert wire_lib.bytes_factor("bf16") == 0.5
    assert wire_lib.bytes_factor("int8-ef", block=256) == \
        pytest.approx(0.25 + 1 / 256)
    # one hop: bytes / bandwidth, normalized to stage-forward units
    u = {c: wire_lib.hop_comm_units(4e6, c, 1e9, 1e-3) for c in
         wire_lib.WIRE_CODECS}
    assert u["fp32"] == pytest.approx(4.0)
    assert u["int8-ef"] < u["bf16"] < u["fp32"]
    # degenerate hardware prices comm at zero instead of dividing by it
    assert wire_lib.hop_comm_units(4e6, "fp32", 0.0, 1e-3) == 0.0


def test_plan_wire_report_prices_classes():
    tplan = plan_lib.plan_for("1f1b", 4, 4, wire="bf16")
    rep = wire_lib.plan_wire_report(tplan, carry_bytes=1000.0)
    assert rep["wire"] == "bf16"
    assert rep["ratio"] == pytest.approx(0.5)
    assert rep["bytes_per_step"] == pytest.approx(
        0.5 * rep["fp32_bytes_per_step"])
    assert rep["bytes_per_tick"] * tplan.n_ticks == pytest.approx(
        rep["bytes_per_step"])
    assert rep["hops"]["chain"] > 0 and rep["hops"]["cotangent_chain"] > 0


# ---------------------------------------------------------------------------
# _Codec kernels: encode at latch, decode at arrival (single host device)
# ---------------------------------------------------------------------------

def _codec(kind, block=256):
    from repro.core.pipeline import _Codec
    return _Codec(kind, block)


@given(st.lists(st.floats(-1e4, 1e4), min_size=1, max_size=300))
@settings(max_examples=30, deadline=None)
def test_bf16_roundtrip_exact_on_representable(vals):
    """bf16 wire is lossless on values already bf16-representable."""
    import jax, jax.numpy as jnp
    x = jnp.asarray(np.array(vals, np.float32))
    x = x.astype(jnp.bfloat16).astype(jnp.float32)   # force representable
    tree = {"h": x, "ids": jnp.arange(x.shape[0], dtype=jnp.int32)}
    c = _codec("bf16")
    wire, ef = c.enc(tree)
    assert ef == ()
    assert wire["h"].dtype == jnp.bfloat16
    out = c.dec(wire, jax.eval_shape(lambda: tree))
    assert np.array_equal(np.asarray(out["h"]), np.asarray(x))
    assert np.array_equal(np.asarray(out["ids"]), np.asarray(tree["ids"]))


@given(st.lists(st.floats(-50, 50), min_size=1, max_size=400),
       st.sampled_from([16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_int8_ef_residual_bounded_by_block_scale(vals, block):
    """One encode leaves a residual no larger than half a quantization
    step of its block (scale = max|block| / 127) — the shrink the EF
    construction relies on: what is left behind is always sub-step."""
    import jax, jax.numpy as jnp
    x = np.array(vals, np.float32)
    c = _codec("int8-ef", block)
    tree = {"h": jnp.asarray(x)}
    ef0 = c.ef_zeros(jax.eval_shape(lambda: tree))
    wire, ef1 = c.enc(tree, ef0)
    resid = np.asarray(ef1["h"])
    n = x.shape[0]
    pad = (-n) % block
    xb = np.pad(x, (0, pad)).reshape(-1, block)
    rb = np.pad(resid, (0, pad)).reshape(-1, block)
    scale = np.maximum(np.abs(xb).max(axis=1) / 127.0, 1e-12)
    assert (np.abs(rb) <= 0.5 * scale[:, None] + 1e-6).all()
    # and the decode matches x up to exactly that residual
    dec = np.asarray(c.dec(wire, jax.eval_shape(lambda: tree))["h"])
    np.testing.assert_allclose(dec + resid, x, rtol=0, atol=1e-5)


@given(st.lists(st.floats(-10, 10), min_size=1, max_size=200))
@settings(max_examples=20, deadline=None)
def test_int8_ef_telescopes_over_repeated_sends(vals):
    """EF algebra: over k sends of a constant value the decoded payloads
    telescope — sum_t dec_t == k * v - ef_k — so the time-averaged wire
    stream converges on the true value instead of accumulating bias."""
    import jax, jax.numpy as jnp
    v = jnp.asarray(np.array(vals, np.float32))
    tree = {"h": v}
    proto = jax.eval_shape(lambda: tree)
    c = _codec("int8-ef", 64)
    ef = c.ef_zeros(proto)
    total = np.zeros_like(np.asarray(v))
    k = 8
    for _ in range(k):
        wire, ef = c.enc(tree, ef)
        total += np.asarray(c.dec(wire, proto)["h"])
    np.testing.assert_allclose(total, k * np.asarray(v) - np.asarray(ef["h"]),
                               rtol=0, atol=1e-3)
    # single-step quantization error can be ~max|v|/254 per element; the
    # k-averaged stream must beat it (EF pushes the bias to O(1/k))
    assert np.abs(total / k - np.asarray(v)).max() \
        <= np.abs(np.asarray(v)).max() / 254.0 + 1e-3


def test_int8_ef_pred_gates_residual_update():
    """The EF residual only advances when the send predicate is true —
    the property that keeps the EF sequence identical across executors
    (mpmd latches every tick; only real sends may touch the state)."""
    import jax, jax.numpy as jnp
    tree = {"h": jnp.linspace(-3.0, 3.0, 50)}
    proto = jax.eval_shape(lambda: tree)
    c = _codec("int8-ef", 16)
    ef0 = c.ef_zeros(proto)
    _, ef_no = c.enc(tree, ef0, pred=jnp.asarray(False))
    _, ef_yes = c.enc(tree, ef0, pred=jnp.asarray(True))
    assert np.array_equal(np.asarray(ef_no["h"]), np.asarray(ef0["h"]))
    assert not np.array_equal(np.asarray(ef_yes["h"]), np.asarray(ef0["h"]))


def test_codec_nonfloat_and_fp32_identity():
    """fp32 is a strict identity; int leaves ride every codec untouched."""
    import jax, jax.numpy as jnp
    tree = {"tok": jnp.arange(12, dtype=jnp.int32),
            "h": jnp.linspace(-1.0, 1.0, 12)}
    proto = jax.eval_shape(lambda: tree)
    for kind in ("fp32", "bf16", "int8-ef"):
        c = _codec(kind, 8)
        ef = c.ef_zeros(proto)
        wire, _ = c.enc(tree, ef)
        out = c.dec(wire, proto)
        assert np.array_equal(np.asarray(out["tok"]),
                              np.asarray(tree["tok"])), kind
        if kind == "fp32":
            assert wire is tree  # identity, not a copy
    # zeros() builds wire-format registers: int8 leaves carry {q, s}
    z = _codec("int8-ef", 8).zeros(proto)
    assert set(z["h"]) == {"q", "s"} and z["h"]["q"].dtype == jnp.int8
    assert z["tok"].dtype == jnp.int32


# ---------------------------------------------------------------------------
# Latch invariant: every route arrival has a one-tick-earlier latch
# ---------------------------------------------------------------------------

SKIPS = [plan_lib.SkipSpec("s02", 0, (2,)), plan_lib.SkipSpec("s13", 1, (3,))]


@pytest.mark.parametrize("schedule", ["gpipe_tasked", "1f1b",
                                      "interleaved:2", "zb"])
@pytest.mark.parametrize("skips", [(), SKIPS],
                         ids=["chain-only", "portal-skips"])
def test_route_latch_invariant(schedule, skips):
    tplan = plan_lib.plan_for(schedule, 4, 4, skips=skips,
                              residuals="recompute")
    checked = plan_lib.assert_route_overlap(tplan)
    n_real = sum((rt.send >= 0).sum() + (rt.g_send >= 0).sum()
                 for rt in tplan.routes)
    if skips:
        assert tplan.routes and checked > 0
        # arrivals and latches pair up one-to-one (plus relay reads)
        assert checked >= len(tplan.routes)
    else:
        assert checked == n_real or not tplan.routes


def test_route_latch_tripwire_catches_violation():
    """Erasing one latch must trip assert_route_overlap — the tripwire
    actually checks the property, it is not vacuously green."""
    tplan = plan_lib.plan_for("1f1b", 4, 4, skips=SKIPS)
    rt = next(r for r in tplan.routes if r.fwd_perm)
    t, r = map(int, next(zip(*np.nonzero(rt.recv >= 0))))
    src = {d: s for s, d in rt.fwd_perm}.get(r, r)
    saved = rt.send[t - 1, src]
    rt.send[t - 1, src] = -1
    try:
        with pytest.raises(AssertionError):
            plan_lib.assert_route_overlap(tplan)
    finally:
        rt.send[t - 1, src] = saved


# ---------------------------------------------------------------------------
# HardwareSpec: new wire fields parse on both YAML paths
# ---------------------------------------------------------------------------

HW_TEXT = """\
name: test-slice
ranks: 2
memory_bytes: 1.0e9      # 1 GB
flops: 1.0e12
ici_bytes_per_s: 1.0e10
link_bandwidth_bytes_per_s: 2.5e9
wire: chain=bf16,portal=fp32,cotangent=int8-ef
"""


def test_hardware_spec_wire_fields(tmp_path):
    from repro.planner.hardware import HardwareSpec, _parse_flat_yaml
    p = tmp_path / "hw.yaml"
    p.write_text(HW_TEXT)
    hw = HardwareSpec.from_yaml(str(p))
    assert hw.link_bandwidth_bytes_per_s == 2.5e9
    assert hw.link_bw == 2.5e9
    assert WireSpec.parse(hw.wire).chain == "bf16"
    # the flat no-PyYAML fallback parses the same schema
    flat = _parse_flat_yaml(HW_TEXT)
    assert HardwareSpec.from_dict(flat) == hw
    # 0 sentinel falls back to the ICI figure
    assert hw.with_(link_bandwidth_bytes_per_s=0.0).link_bw == 1.0e10
    with pytest.raises(ValueError):
        hw.with_(wire="fp64")
    with pytest.raises(ValueError):
        hw.with_(link_bandwidth_bytes_per_s=-1.0)


# ---------------------------------------------------------------------------
# EFCompressor regression: pytrees containing tuples (satellite fix)
# ---------------------------------------------------------------------------

def test_ef_compressor_tuple_pytree_roundtrip():
    """compress_reduce must treat tuples as structure, not leaves — the
    old unflatten special-cased `isinstance(x, tuple)` and corrupted
    grads whose pytree contains tuple nodes."""
    import jax, jax.numpy as jnp
    from repro.runtime.compression import EFCompressor
    k = jax.random.PRNGKey(0)
    g = {"attn": (jax.random.normal(k, (33,)),
                  jax.random.normal(jax.random.fold_in(k, 1), (4, 5))),
         "mlp": (jax.random.normal(jax.random.fold_in(k, 2), (7,)),)}
    comp = EFCompressor(block=16)
    ef = comp.init_state(g)
    out, ef2 = comp.compress_reduce(g, ef)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(g)
    assert jax.tree_util.tree_structure(ef2) == \
        jax.tree_util.tree_structure(g)
    # dequantized + residual reconstructs every leaf exactly, leaf-aligned
    # with the ORIGINAL tree (the old tuple special-case mis-split here)
    for ga, oa, ea in zip(jax.tree_util.tree_leaves(g),
                          jax.tree_util.tree_leaves(out),
                          jax.tree_util.tree_leaves(ef2)):
        assert oa.shape == ga.shape
        np.testing.assert_allclose(np.asarray(oa) + np.asarray(ea),
                                   np.asarray(ga), rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# Multi-device executor: wire modes bitwise across spmd/mpmd; int8-ef
# passes the single-device oracle + 5-step loss-curve check
# ---------------------------------------------------------------------------

WIRE_PARITY = """
import zlib
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core.pipeline import pipeline_grad_call, microbatch, unmicrobatch

key = jax.random.PRNGKey(0)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")

def lm_lg(schedule, pipe, m, executor, wire="fp32"):
    # whisper-tiny: encoder-decoder portals, so the route latch path and
    # the portal/cotangent codec classes are all exercised
    arch = configs.smoke_arch("whisper-tiny")
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                          remat="full", schedule=schedule,
                          residuals="recompute", executor=executor,
                          wire=wire)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = {}
    for k, v in model.input_specs(shape).items():
        kk = jax.random.fold_in(key, zlib.crc32(k.encode()) % 1000)
        batch[k] = (jax.random.randint(kk, v.shape, 0, arch.vocab)
                    if v.dtype == jnp.int32
                    else jax.random.normal(kk, v.shape, v.dtype) * 0.1)
    mbg = shape.global_batch // m
    cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
    with set_mesh(mesh):
        pg, _ = pipeline_grad_call(
            model.make_stage_apply(model.consts()), mesh=mesh, cfg=pcfg,
            loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"],
                                                      la["labels"]),
            skips=model.skips(), skip_protos=model.skip_protos(mbg, 16),
            carry_proto=cp)
        @jax.jit
        def fused(p, b):
            fresh, evjp = jax.vjp(
                lambda e: model.embed_inputs(e, b), p["embed"])
            head_ps = {"head": p["head"], "embed": p["embed"]}
            loss, gs, gh, ig = pg(p["stages"], head_ps, microbatch(fresh, m),
                                  microbatch({"labels": b["labels"]}, m))
            (ge,) = evjp(unmicrobatch(ig))
            ge = jax.tree.map(jnp.add, ge, gh["embed"])
            return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
        loss, grads = fused(params, batch)
    return np.asarray(loss), jax.tree.map(np.asarray, grads)

def gflat(g):
    return np.concatenate([np.ravel(l) for l in jax.tree.leaves(g)])

base = lm_lg("1f1b", 2, 4, "spmd")
for wire in ("fp32", "bf16", "int8-ef",
             "chain=fp32,portal=int8-ef,cotangent=bf16"):
    s = lm_lg("1f1b", 2, 4, "spmd", wire=wire)
    m_ = lm_lg("1f1b", 2, 4, "mpmd", wire=wire)
    # the core contract survives the codec: spmd == mpmd BITWISE in loss
    # and grads for every wire mode (EF updates are send-predicated)
    assert np.array_equal(s[0], m_[0]), (wire, s[0], m_[0])
    assert np.array_equal(gflat(s[1]), gflat(m_[1])), wire
    if wire == "fp32":
        # lossless mode: bitwise against the unwired baseline semantics
        assert np.array_equal(s[0], base[0])
        assert np.array_equal(gflat(s[1]), gflat(base[1]))
    else:
        rel = abs(float(s[0]) - float(base[0])) / abs(float(base[0]))
        assert rel < 0.05, (wire, rel)
    print("wire parity OK", wire)
print("WIRE PARITY OK")
"""

INT8_ORACLE = """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.core.pipeline import (TickCtx, pipeline_grad_call, microbatch,
                                 unmicrobatch)
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
key = jax.random.PRNGKey(0)
shape = ShapeConfig("t", seq_len=16, global_batch=16, kind="train")
m = 4
batch_of = lambda model: {
    k: jax.random.randint(jax.random.fold_in(key, len(k)), v.shape, 0,
                          arch.vocab)
    for k, v in model.input_specs(shape).items()}

def curve(wire, executor):
    pcfg = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=m,
                          schedule="1f1b", executor=executor, wire=wire)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    batch = batch_of(model)
    ocfg = optim.OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)
    with set_mesh(mesh):
        step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape,
                                              ocfg))
        p, o = params, optim.init(ocfg, params)
        ls = []
        for _ in range(5):
            p, o, metrics = step(p, o, batch)
            ls.append(float(metrics["loss"]))
    return model, params, batch, ls

# 5-step loss-curve check: the int8-ef wire must track the lossless
# curve within 5% at every step and still make training progress
model, params, batch, base = curve("fp32", "mpmd")
_, _, _, lossy = curve("int8-ef", "mpmd")
print("fp32   :", base)
print("int8-ef:", lossy)
np.testing.assert_allclose(lossy, base, rtol=5e-2)
assert lossy[-1] < lossy[0]

# single-shot grads vs a from-scratch single-device jax.grad oracle, to
# the stated int8-ef tolerances (one quantized hop per boundary; the EF
# state is cold on step one, so the error is pure quantization noise)
stage_apply = model.make_stage_apply(model.consts())
def oracle_loss(p, b):
    fresh = model.embed_inputs(p["embed"], b)
    fresh_mb = jax.tree.map(
        lambda a: a.reshape((m, a.shape[0] // m) + a.shape[1:]), fresh)
    labels_mb = b["labels"].reshape(
        (m, b["labels"].shape[0] // m) + b["labels"].shape[1:])
    hp = {"head": p["head"], "embed": p["embed"]}
    total = jnp.zeros((), jnp.float32)
    for i in range(m):
        fresh_i = jax.tree.map(lambda a: a[i], fresh_mb)
        carry = {"h": jnp.zeros_like(fresh_i["h"])}
        for s in range(model.n_stages):
            ctx = TickCtx(stage=jnp.int32(s), micro=jnp.int32(i),
                          valid=jnp.asarray(True), t=jnp.int32(0),
                          fresh=fresh_i, n_stages=model.n_stages, n_micro=m)
            p_s = jax.tree.map(lambda a: a[s], p["stages"])
            carry, _, _ = stage_apply(p_s, carry, {}, {}, ctx)
        total = total + model.head_loss(hp, carry["h"],
                                        labels_mb[i]).astype(jnp.float32)
    return total / m

o_loss, o_grads = jax.jit(jax.value_and_grad(oracle_loss))(params, batch)
pcfg = ParallelConfig(pipe=2, tp=1, data=1, pod=1, n_micro=m,
                      schedule="1f1b", executor="mpmd", wire="int8-ef")
mesh = mesh_lib.make_smoke_mesh(pcfg)
mbg = shape.global_batch // m
cp = {"h": jax.ShapeDtypeStruct((mbg, 16, arch.d_model), jnp.float32)}
with set_mesh(mesh):
    pg, _ = pipeline_grad_call(
        stage_apply, mesh=mesh, cfg=pcfg,
        loss_fn=lambda hp, c, la: model.head_loss(hp, c["h"], la["labels"]),
        skips=model.skips(), skip_protos=model.skip_protos(mbg, 16),
        carry_proto=cp)
    @jax.jit
    def fused(p, b):
        fresh, evjp = jax.vjp(lambda e: model.embed_inputs(e, b), p["embed"])
        hp = {"head": p["head"], "embed": p["embed"]}
        loss, gs, gh, ig = pg(p["stages"], hp, microbatch(fresh, m),
                              microbatch({"labels": b["labels"]}, m))
        (ge,) = evjp(unmicrobatch(ig))
        ge = jax.tree.map(jnp.add, ge, gh["embed"])
        return loss, {"embed": ge, "stages": gs, "head": gh["head"]}
    loss, grads = fused(params, batch)
np.testing.assert_allclose(float(o_loss), float(loss), rtol=2e-3)
for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(o_grads)[0],
                        jax.tree_util.tree_leaves(grads)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=2e-3,
                               err_msg=f"int8-ef oracle {path}")
print("INT8 ORACLE OK")
"""


def test_wire_executor_parity():
    """Every wire mode is bitwise-identical across spmd/mpmd (loss AND
    grads) on the portal model; fp32 is additionally bitwise against the
    unwired baseline, lossy modes land within 5% of its loss."""
    out = run_subprocess(WIRE_PARITY, n_devices=8, timeout=2400)
    assert "WIRE PARITY OK" in out


def test_wire_int8_oracle_tolerance():
    """int8-ef wire passes the single-device oracle to stated tolerances
    (grads rtol=5e-3/atol=2e-3 — step one ships cold-EF quantization
    noise) and tracks the lossless 5-step loss curve within 5% while
    still training."""
    out = run_subprocess(INT8_ORACLE, n_devices=8, timeout=2400)
    assert "INT8 ORACLE OK" in out
