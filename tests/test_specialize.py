"""MPMD specialization invariants (``plan.specialize``).

Hypothesis suites prove, for random schedules and (m, n, v) shapes, that
each rank's specialized program is a faithful projection of the global
plan:

* **branch pruning is exact** — for every rank and every segment (both
  the rank program's own segment cuts and the global executor segments),
  the specialized branch set equals the set of kinds actually present in
  that rank's column over the window: nothing a rank never runs is
  traced, nothing it runs is missing.
* **per-rank buffer depths are the schedule predictions** — a rank
  program's park / residual depth equals ``schedules.peak_park`` /
  ``schedules.peak_residuals`` restricted to that rank (so 1F1B's rank 0
  declares 0 park slots while the SPMD plan flattens to the ring max),
  and every slot index in the rank's columns stays below its declared
  depth.
* **double-buffer latch columns are consistent** — ``send_slot`` marks
  exactly the F ticks whose global stage ships a boundary output
  (``stage < n_stages - 1``), ``b_send_slot`` exactly the backward-chain
  ticks with ``stage > 0``, and every park / inbox arrival is preceded by
  a matching latch one tick earlier (the arrival an overlapped ship can
  deliver).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import plan as PL
from repro.core import schedules as S

mn = st.tuples(st.integers(1, 12), st.integers(1, 6))
schedule_names = st.sampled_from(["gpipe_tasked", "1f1b", "zb"])


def build(schedule, m, n, residuals="recompute"):
    if schedule == "interleaved:2":
        m = max(1, (m // n) * n) if m >= n else n   # needs m % n == 0
    return PL.plan_for(schedule, m, n, residuals=residuals), m


@given(schedule_names, mn)
@settings(max_examples=40, deadline=None)
def test_rank_branch_sets_are_exact(schedule, m_n):
    m, n = m_n
    tplan, m = build(schedule, m, n)
    for r in range(n):
        prog = PL.specialize(tplan, r)
        col = tplan.kind[:, r]
        assert np.array_equal(prog.kind, col)
        # rank-program segments: exact branch sets, full tick coverage
        assert prog.segments[0].start == 0
        assert prog.segments[-1].stop == tplan.n_ticks
        for a, b in zip(prog.segments, prog.segments[1:]):
            assert a.stop == b.start
        for seg in prog.segments:
            present = tuple(sorted(set(int(k)
                                       for k in col[seg.start:seg.stop])))
            assert seg.kinds == present, (schedule, r, seg)
            assert prog.branches_in(seg.start, seg.stop) == present
        # global executor segments: the per-rank pruned set the MPMD
        # lowering traces is exactly what the column contains there
        for seg in tplan.segments:
            present = set(int(k) for k in col[seg.start:seg.stop])
            assert present <= set(seg.kinds), (schedule, r, seg)
            assert prog.branches_in(seg.start, seg.stop) \
                == tuple(sorted(present))


@given(schedule_names, mn)
@settings(max_examples=40, deadline=None)
def test_rank_depths_match_schedule_predictions(schedule, m_n):
    m, n = m_n
    residuals = "reuse" if schedule == "zb" else "recompute"
    tplan, m = build(schedule, m, n, residuals=residuals)
    table, n_stages, ranks = PL.schedule_table(schedule, m, n)
    park = S.peak_park(table, n_stages, ranks=ranks)
    resid = S.peak_residuals(table, n_stages, ranks=ranks)
    for r in range(n):
        prog = PL.specialize(tplan, r)
        assert prog.park_depth == park[r], (schedule, r)
        if tplan.residuals == "reuse":
            assert prog.resid_depth == resid[r], (schedule, r)
        else:
            assert prog.resid_depth == 0
        # every slot a column touches fits the declared depth
        for colm, depth in ((prog.park_recv, prog.park_depth),
                            (prog.park_read, prog.park_depth),
                            (prog.b_recv, prog.b_inbox_depth),
                            (prog.b_read, prog.b_inbox_depth)):
            used = colm[colm >= 0]
            if used.size:
                assert int(used.max()) < depth, (schedule, r)
        slots = prog.buffer_slots()
        assert slots["park"] == park[r]
    # the MPMD headline: some rank declares strictly fewer park slots
    # than the SPMD ring max whenever the park profile is non-uniform
    if len(set(tplan.per_stage_park)) > 1:
        assert min(PL.specialize(tplan, r).park_depth
                   for r in range(n)) < tplan.park_depth


@given(schedule_names, mn)
@settings(max_examples=40, deadline=None)
def test_send_latch_columns(schedule, m_n):
    m, n = m_n
    tplan, m = build(schedule, m, n)
    split = bool((tplan.kind == PL.BWD_X).any())
    for t in range(tplan.n_ticks):
        for r in range(n):
            k = int(tplan.kind[t, r])
            s = int(tplan.chunk[t, r]) * n + r
            want_f = k == PL.FWD and s < tplan.n_stages - 1
            assert (tplan.send_slot[t, r] >= 0) == want_f, (t, r)
            bk = PL.BWD_X if split else PL.BWD
            want_b = k == bk and s > 0
            assert (tplan.b_send_slot[t, r] >= 0) == want_b, (t, r)
    # every chain arrival is deliverable by the one-tick-ahead ship: a
    # park/inbox recv at tick t requires a latch somewhere at t-1
    for t in range(tplan.n_ticks):
        if (tplan.park_recv[t] >= 0).any():
            assert t > 0 and (tplan.send_slot[t - 1] >= 0).any(), t
        if (tplan.b_recv[t] >= 0).any():
            assert t > 0 and (tplan.b_send_slot[t - 1] >= 0).any(), t


def test_specialize_interleaved_and_validation():
    """Chunked plans specialize per physical rank (both chunks' columns);
    out-of-range ranks are rejected."""
    tplan = PL.plan_for("interleaved:2", 8, 4)
    for r in range(4):
        prog = PL.specialize(tplan, r)
        assert prog.n_ticks == tplan.n_ticks
        assert set(int(c) for c in prog.chunk[prog.kind != PL.NOP]) \
            == {0, 1}
        assert prog.park_depth == tplan.per_stage_park[r]
    with pytest.raises(ValueError):
        PL.specialize(tplan, 4)
    with pytest.raises(ValueError):
        PL.specialize(tplan, -1)


def test_specialize_1f1b_rank0_parks_nothing():
    """The memory headline restated as a concrete table: at pipe=4, m=8,
    1F1B's rank 0 program declares 0 park slots while the SPMD plan
    allocates the ring max on every rank."""
    tplan = PL.plan_for("1f1b", 8, 4)
    progs = [PL.specialize(tplan, r) for r in range(4)]
    assert progs[0].park_depth == 0
    assert tplan.park_depth == max(p.park_depth for p in progs)
    assert tplan.park_depth > 0
    # fill window: rank 0 is branch-free F while late ranks still idle
    first = progs[0].segments[0]
    assert first.kinds == (PL.FWD,)
