"""The framework's core guarantee: the pipelined computation (any pipe/m/
data split, with checkpointing and portals) computes EXACTLY the same loss
and gradients as plain sequential execution.

These run in subprocesses with 8 XLA host devices (the main test process
must keep seeing 1 device per the assignment).
"""
import pytest

from conftest import run_subprocess

EQUIV_TEMPLATE = """
import zlib, dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.lm import LMModel
from repro.core.pipeline import (pipeline_call, microbatch,
                                 last_stage_output, unmicrobatch)

name = {name!r}
arch = configs.smoke_arch(name)
shape = ShapeConfig("t", seq_len=16, global_batch=8, kind="train")
key = jax.random.PRNGKey(0)

def run(pcfg):
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    with set_mesh(mesh):
        consts = model.consts()
        mbg = shape.global_batch // pcfg.n_micro
        pipe = pipeline_call(
            model.make_stage_apply(consts), mesh=mesh, cfg=pcfg,
            skips=model.skips(),
            skip_protos=model.skip_protos(mbg, shape.seq_len),
            carry_proto={{"h": jax.ShapeDtypeStruct(
                (mbg, shape.seq_len, arch.d_model), jnp.float32)}})
        def loss_fn(p, batch):
            fresh = model.embed_inputs(p["embed"], batch)
            outs, _ = pipe(p["stages"], microbatch(fresh, pcfg.n_micro), None)
            h = unmicrobatch(last_stage_output(outs)["h"])
            return model.head_loss(p, h, batch["labels"])
        batch = {{}}
        for k, v in model.input_specs(shape).items():
            kk = jax.random.fold_in(key, zlib.crc32(k.encode()) % 1000)
            batch[k] = (jax.random.randint(kk, v.shape, 0, arch.vocab)
                        if v.dtype == jnp.int32
                        else jax.random.normal(kk, v.shape, v.dtype) * 0.1)
        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
        return np.asarray(loss), jax.tree.map(np.asarray, grads)

l_ref, g_ref = run(ParallelConfig(pipe=1, tp=1, data=1, pod=1, n_micro=1,
                                  remat="none", portals={portals}))
l_pp, g_pp = run(ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=4,
                                remat={remat!r}, portals={portals},
                                overlap={overlap}))
np.testing.assert_allclose(l_ref, l_pp, rtol=2e-5)
ref_leaves = jax.tree_util.tree_flatten_with_path(g_ref)[0]
pp_leaves = jax.tree_util.tree_leaves(g_pp)
for (path, a), b in zip(ref_leaves, pp_leaves):
    if a.ndim >= 2 and a.shape[:2] != b.shape[:2]:
        a = a.reshape((-1,) + a.shape[2:])
        b = b.reshape((-1,) + b.shape[2:])
        nmin = min(a.shape[0], b.shape[0])
        if b.shape[0] > nmin:
            assert np.abs(b[nmin:]).max() == 0.0, \\
                f"identity-pad layers must get zero grads: {{path}}"
        a, b = a[:nmin], b[:nmin]
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                               err_msg=str(path))
print("EQUIV OK", name)
"""


@pytest.mark.parametrize("name,remat,portals,overlap", [
    ("smollm-360m", "full", True, True),     # dense + remat
    ("smollm-360m", "none", True, True),     # no checkpointing
    ("smollm-360m", "dots", True, False),    # policy remat + no-overlap path
    ("whisper-tiny", "full", True, True),    # enc-dec through PORTALS
    ("whisper-tiny", "full", False, True),   # enc-dec THREADED (paper §3.3)
    ("mixtral-8x7b", "full", True, True),    # MoE + SWA
    ("rwkv6-1.6b", "full", True, True),      # attention-free recurrence
    ("hymba-1.5b", "full", True, True),      # hybrid attn+SSM, mixed windows
])
def test_pipeline_equals_sequential(name, remat, portals, overlap):
    run_subprocess(EQUIV_TEMPLATE.format(name=name, remat=remat,
                                         portals=portals, overlap=overlap),
                   n_devices=8, timeout=900)


TRAIN_LOOP = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.launch import mesh as mesh_lib, steps, sharding
from repro.models.lm import LMModel
from repro.optim import optimizers as optim

arch = configs.smoke_arch("deepseek-7b")
pcfg = ParallelConfig(pipe=2, tp=2, data=2, pod=1, n_micro=2)
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = LMModel(arch, pcfg, dtype=jnp.float32)
shape = ShapeConfig("t", seq_len=16, global_batch=8, kind="train")
params = model.init(jax.random.PRNGKey(0))
ocfg = optim.OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)
opt = optim.init(ocfg, params)
with set_mesh(mesh):
    step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))
    batch = {}
    key = jax.random.PRNGKey(1)
    for k, v in model.input_specs(shape).items():
        batch[k] = jax.random.randint(key, v.shape, 0, arch.vocab)
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0] * 0.9, losses
print("SHARDED TRAIN OK", losses[0], "->", losses[-1])
"""


def test_sharded_train_loop_converges():
    """Full train step (pipeline + FSDP + TP + DP + AdamW) on an 8-device
    mesh memorizes a fixed batch."""
    run_subprocess(TRAIN_LOOP, n_devices=8, timeout=900)
