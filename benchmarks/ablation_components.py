"""Paper Table 1 analogue: optimization components added incrementally.

torchgpipe's ablation (U-Net, 4 partitions, m=8) toggles [backward
dependency, copy streams, portals].  Under XLA the backward dependency (C2)
is structural — DESIGN.md §2 — so the measurable axes here are:

  row 0  baseline      serialized comm (optimization_barrier between compute
                       and sends = the "default stream" behaviour), skips
                       threaded through every stage, no checkpointing
  row 1  +checkpoint   per-(i,j) remat (GPipe memory behaviour)
  row 2  +overlap      async sends (copy-stream analogue)
  row 3  +portals      direct skip routing (thinner boundary buffers)

Reported per row: wall-clock throughput on an 8-host-device pipeline (n=4,
data=2), per-device compiled memory, and collective-permute link bytes from
the compiled HLO (the quantity Fig. 7's red bars visualize).
"""
import json

BENCH = """
import time, json
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.unet import UNetConfig, UNetModel
from repro.models import pipeline_hetero as PH
from repro.roofline import analysis as RA

cfg = UNetConfig(B={B}, C={C}, levels=4, img={img})
B_GLOBAL = 16
rows = []
for name, kw in [
    ("baseline", dict(overlap=False, portals=False, remat="none")),
    ("+checkpoint", dict(overlap=False, portals=False, remat="full")),
    ("+overlap", dict(overlap=True, portals=False, remat="full")),
    ("+portals", dict(overlap=True, portals=True, remat="full")),
]:
    pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=8, **kw)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = UNetModel(cfg, pcfg.pipe)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (B_GLOBAL, cfg.img, cfg.img, cfg.in_ch))
    y = jax.random.normal(jax.random.PRNGKey(2),
                          (B_GLOBAL, cfg.img, cfg.img, cfg.out_ch))
    prog = PH.build_hetero_program(model, params,
                                   B_GLOBAL // pcfg.n_micro, pcfg, x[:2])
    with set_mesh(mesh):
        def loss(p, xx, yy):
            import repro.models.pipeline_hetero as P2
            prog2 = PH.HeteroProgram(p, prog.stage_apply, prog.carry_proto,
                                     prog.skips, prog.skip_protos,
                                     prog.out_proto)
            out = PH.hetero_forward(prog2, mesh, pcfg, xx)
            return jnp.mean((out - yy) ** 2)
        step = jax.jit(jax.grad(loss))
        g = step(prog.stacked_params, x, y)
        jax.block_until_ready(g)
        t0 = time.perf_counter()
        for _ in range(3):
            g = step(prog.stacked_params, x, y)
        jax.block_until_ready(g)
        dt = (time.perf_counter() - t0) / 3
        co = step.lower(prog.stacked_params, x, y).compile()
        mem = co.memory_analysis()
        cost = RA.analyze_hlo(co.as_text(), mesh.size)
    rows.append(dict(name=name, samples_per_s=B_GLOBAL / dt,
                     step_s=dt,
                     temp_gib=mem.temp_size_in_bytes / 2**30,
                     permute_bytes=cost.coll_link_bytes.get(
                         "collective-permute", 0.0)))
print("RESULT " + json.dumps(rows))
"""


def run(B=1, C=8, img=64):
    from benchmarks.util import run_with_devices
    out = run_with_devices(BENCH.format(B=B, C=C, img=img), 8, timeout=2400)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no result in output:\n{out[-2000:]}")


def main():
    rows = run()
    base = rows[0]["samples_per_s"]
    print("name,us_per_call,derived")
    for r in rows:
        speedup = r["samples_per_s"] / base
        print(f"ablation/{r['name']},{r['step_s']*1e6:.0f},"
              f"speedup={speedup:.3f};mem_gib={r['temp_gib']:.3f};"
              f"permute_bytes={r['permute_bytes']:.3e}")


if __name__ == "__main__":
    main()
