"""Paper Table 3 analogue: largest U-Net that fits per pipeline width.

The paper grows (B, C) until n GPUs (22 GiB each) are occupied.  Here the
fit test is ``memory_analysis()`` of the compiled train step against a
proportionally scaled budget (1 GiB/device at quarter-scale C, img=96 —
the paper-scale ladder's fp32 host arrays exceed this container's RAM):
for each n we report the largest configuration whose per-device footprint
(params + grads + activations with checkpointing) fits — reproducing the
table's "more stages => superlinearly bigger model" trend under
rematerialization.
"""
import json

BENCH = """
import json
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.unet import UNetConfig, UNetModel
from repro.models import pipeline_hetero as PH

n = {n}
BUDGET = 1 * 2**30
rows = []
for (B, C) in {ladder}:
    cfg = UNetConfig(B=B, C=C, levels=5, img=96)
    pcfg = ParallelConfig(pipe=n, tp=1, data=1, pod=1, n_micro=8,
                          remat="full")
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = UNetModel(cfg, pcfg.pipe)
    try:
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params = jax.tree.map(
            lambda p: jnp.zeros(p.shape, p.dtype), params)
        x = jax.ShapeDtypeStruct((32, 96, 96, 3), jnp.float32)
        y = jax.ShapeDtypeStruct((32, 96, 96, 1), jnp.float32)
        prog = PH.build_hetero_program(model, params, 32 // 8, pcfg,
                                       jax.ShapeDtypeStruct((4, 96, 96, 3),
                                                            jnp.float32))
        with set_mesh(mesh):
            def loss(p, xx, yy):
                prog2 = PH.HeteroProgram(p, prog.stage_apply,
                                         prog.carry_proto, prog.skips,
                                         prog.skip_protos, prog.out_proto)
                out = PH.hetero_forward(prog2, mesh, pcfg, xx)
                return jnp.mean((out - yy) ** 2)
            co = jax.jit(jax.grad(loss)).lower(
                jax.eval_shape(lambda: prog.stacked_params), x, y).compile()
        mem = co.memory_analysis()
        per_dev = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                   + mem.output_size_in_bytes)
        rows.append(dict(B=B, C=C, params=model.total_params(),
                         per_dev_gib=per_dev / 2**30,
                         fits=bool(per_dev <= BUDGET)))
    except Exception as e:
        rows.append(dict(B=B, C=C, error=str(e)[:200]))
print("RESULT " + json.dumps(dict(n=n, rows=rows)))
"""

LADDER = [(2, 18), (6, 24), (12, 32), (20, 40)]


def run(ns=(1, 2, 4), ladder=LADDER):
    from benchmarks.util import run_with_devices
    out = []
    for n in ns:
        txt = run_with_devices(BENCH.format(n=n, ladder=list(ladder)),
                               max(n, 2), timeout=3000)
        for line in txt.splitlines():
            if line.startswith("RESULT "):
                out.append(json.loads(line[len("RESULT "):]))
    return out


def main(ns=(1, 2, 4), ladder=LADDER):
    results = run(ns, ladder)
    print("name,us_per_call,derived")
    for res in results:
        best = None
        for r in res["rows"]:
            if r.get("fits"):
                best = r
        if best:
            print(f"unet_memory/pipeline-{res['n']},0,"
                  f"max_BC=({best['B']}:{best['C']});"
                  f"params={best['params']/1e6:.1f}M;"
                  f"mem_gib={best['per_dev_gib']:.1f}")
        else:
            print(f"unet_memory/pipeline-{res['n']},0,none_fit")


if __name__ == "__main__":
    main()
