"""Benchmark harness — one function per paper table (+ the assignment's
roofline table).  Prints ``name,us_per_call,derived`` CSV.

Tables (torchgpipe paper):
  Table 1  component ablation        -> ablation_components
  Table 2  AmoebaNet-D speed (m, n)  -> amoebanet_speed
  Table 3  U-Net max model vs n      -> unet_memory
  Table 4  U-Net speed vs n          -> unet_speed
Assignment:
  roofline per (arch x shape x mesh) -> roofline_table (reads dry-run JSON)

Wall-clock numbers run real multi-device pipelines on XLA host devices in
subprocesses (reduced model sizes — CPU is the runtime, TPU the target);
memory/collective numbers come from compiled artifacts.  ``--fast`` trims
the grids.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids (default: full paper grids)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: ablation,amoebanet,"
                         "unet_memory,unet_speed,roofline,schedules")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (ablation_components, amoebanet_speed,
                            roofline_table, schedules_bench, unet_memory,
                            unet_speed)

    def want(name):
        return only is None or name in only

    if want("schedules"):
        print("# Schedules: GPipe vs 1F1B vs interleaved vs zb step time"
              " + donated activation stash (-> BENCH_schedules.json)")
        grid = ((2, 4),) if args.fast else ((2, 4), (4, 4), (4, 8))
        _safe(lambda: schedules_bench.main(grid=grid))
    if want("ablation"):
        print("# Table 1: optimization components (U-Net, n=4, m=8)")
        _safe(ablation_components.main)
    if want("amoebanet"):
        print("# Table 2: AmoebaNet-D speed benchmark (m x n)")
        grid = ((1, 2), (4, 2), (4, 4), (4, 8)) if args.fast else None
        _safe(lambda: amoebanet_speed.main(grid=grid))
    if want("unet_memory"):
        print("# Table 3: U-Net memory benchmark")
        ns = (1, 2) if args.fast else (1, 2, 4)
        _safe(lambda: unet_memory.main(ns=ns))
    if want("unet_speed"):
        print("# Table 4: U-Net speed benchmark")
        cols = unet_speed.COLUMNS[:3] if args.fast else unet_speed.COLUMNS
        _safe(lambda: unet_speed.main(columns=cols))
    if want("roofline"):
        print("# Assignment: roofline table (from dry-run artifacts)")
        _safe(roofline_table.main)


def _safe(fn):
    try:
        fn()
    except Exception:
        traceback.print_exc()
        print("bench_failed,0,see_traceback", file=sys.stdout)


if __name__ == "__main__":
    main()
