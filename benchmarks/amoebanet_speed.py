"""Paper Table 2 analogue: AmoebaNet-D throughput for m x n pipeline grids.

The paper trains AmoebaNet-D (18, 256) on 224x224 synthetic images with
plain SGD and reports relative throughput for m in {1, 4, 32}, n in
{2, 4, 8}, baseline (m, n) = (1, 2).  Hardware here is XLA host devices, so
the model is scaled down (L=9, F=32, img=64) but the schedule/bubble
behaviour being measured is shape-independent.  m=1 applies checkpointing
to the last (only) micro-batch, matching the paper's footnote-5 comparison.
"""
import json

BENCH = """
import time, json, sys, types
import jax, jax.numpy as jnp
_m = types.ModuleType("benchmarks_schedule_model")
def _schedule_time(costs, sizes, m, remat=True):
    # per-SAMPLE critical path (see unet_speed).
    bounds = [0]
    for s in sizes: bounds.append(bounds[-1] + s)
    stage = [sum(costs[bounds[j]:bounds[j+1]]) for j in range(len(sizes))]
    n = len([s for s in sizes if s > 0])
    per_tick = max(stage) * (1.0 + (3.0 if remat else 2.0))
    return (m + n - 1) / m * per_tick
_m.schedule_time = _schedule_time
sys.modules["benchmarks_schedule_model"] = _m
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.amoebanet import AmoebaConfig, AmoebaNetModel
from repro.models import pipeline_hetero as PH

cfg = AmoebaConfig(L={L}, F={F}, img={img}, n_classes=100)
m, n = {m}, {n}
B_GLOBAL = max(16, m * 2)
pcfg = ParallelConfig(pipe=n, tp=1, data=1, pod=1, n_micro=m, remat="full",
                      remat_last_micro=(m == 1))
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = AmoebaNetModel(cfg, pcfg.pipe)
params = model.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (B_GLOBAL, cfg.img, cfg.img, 3))
labels = jax.random.randint(jax.random.PRNGKey(2), (B_GLOBAL,), 0, 100)
prog = PH.build_hetero_program(model, params, B_GLOBAL // m, pcfg, x[:2])
with set_mesh(mesh):
    def loss(p, xx, yy):
        prog2 = PH.HeteroProgram(p, prog.stage_apply, prog.carry_proto,
                                 prog.skips, prog.skip_protos, prog.out_proto)
        logits = PH.hetero_forward(prog2, mesh, pcfg, xx)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.take_along_axis(logp, yy[:, None], 1).mean()
    step = jax.jit(jax.grad(loss))
    g = step(prog.stacked_params, x, labels)
    jax.block_until_ready(g)
    t0 = time.perf_counter()
    for _ in range(3):
        g = step(prog.stacked_params, x, labels)
    jax.block_until_ready(g)
    dt = (time.perf_counter() - t0) / 3
costs = [c.flops() for c in model.layers]
from benchmarks_schedule_model import schedule_time  # injected below
print("RESULT " + json.dumps(dict(m=m, n=n, samples_per_s=B_GLOBAL/dt,
                                  step_s=dt,
                                  pred_t=schedule_time(costs, model.sizes, m))))
"""


def run(L=9, F=32, img=64, grid=((1, 2), (4, 2), (32, 2),
                                 (1, 4), (4, 4), (32, 4),
                                 (1, 8), (4, 8), (32, 8))):
    from benchmarks.util import run_with_devices
    rows = []
    for m, n in grid:
        out = run_with_devices(BENCH.format(L=L, F=F, img=img, m=m, n=n),
                               max(n, 2), timeout=2400)
        for line in out.splitlines():
            if line.startswith("RESULT "):
                rows.append(json.loads(line[len("RESULT "):]))
    return rows


def main(grid=None):
    rows = run(**({"grid": grid} if grid else {}))
    base = next(r for r in rows if (r["m"], r["n"]) == (1, 2))["samples_per_s"]
    print("name,us_per_call,derived")
    for r in rows:
        basep = next(x for x in rows if (x["m"], x["n"]) == (1, 2))["pred_t"]
        print(f"amoebanet/m{r['m']}_n{r['n']},{r['step_s']*1e6:.0f},"
              f"measured_1core={r['samples_per_s']/base:.3f};"
              f"predicted_speedup={basep/r['pred_t']:.2f}")


if __name__ == "__main__":
    main()
