"""Benchmark helpers: subprocess launch (to control device count) + timing."""
import os
import subprocess
import sys
import textwrap
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO_ROOT, "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 1800) -> str:
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import sys
        sys.path.insert(0, {SRC!r})
    """)
    proc = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout


def schedule_time(costs, sizes, m: int, *, remat: bool = True,
                  comm_per_hop: float = 0.0) -> float:
    """GPipe critical-path model for one mini-batch of m micro-batches.

    costs: per-layer costs; sizes: layers per stage (balance output).
    fwd ticks cost max_j(stage fwd); bwd ticks cost max_j(stage bwd) where
    bwd = 2x fwd (+1x recompute under checkpointing).  This container has a
    single physical core, so wall-clock cannot exhibit parallel speedup —
    the assignment's speed tables therefore report this model (fed by the
    compiled per-layer FLOPs) alongside the measured 1-core times.
    """
    bounds = [0]
    for s in sizes:
        bounds.append(bounds[-1] + s)
    stage = [sum(costs[bounds[j]:bounds[j + 1]]) for j in range(len(sizes))]
    n = len([s for s in sizes if s > 0])
    cf = max(stage) + comm_per_hop
    cb = max(stage) * (3.0 if remat else 2.0) + comm_per_hop
    return (m + n - 1) * (cf + cb)


def sequential_time(costs, m: int) -> float:
    """No pipeline, no checkpointing: m micro-batches through all layers."""
    return m * sum(costs) * 3.0


def time_fn(fn, *args, iters: int = 3, warmup: int = 1):
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters
