"""Schedule A/B benchmark: GPipe vs 1F1B vs interleaved vs zero-bubble,
crossed with the executor lowering (SPMD reference vs MPMD per-rank
specialized programs).

Runs the fused scheduler (``gpipe_tasked`` / ``1f1b`` / ``interleaved:2`` /
``zb`` / ``zb-reuse``) and the legacy-semantics autodiff path (``gpipe``,
the forward-only plan through the same executor) on real multi-device
pipelines (XLA host devices, reduced model — CPU is the runtime, TPU the
target) and emits a machine-readable ``BENCH_schedules.json`` so the perf
trajectory has a baseline.  ``zb-reuse`` is ``schedule="zb"`` with
``residuals="reuse"`` + ``remat="dots"`` (true ZB-H1: Bx stashes the
matmul outputs its remat materialized, Bw re-reads them instead of
recomputing — Bw is priced at 1 forward instead of 2), A/B'd against
recompute-mode ``zb`` with its residual-stash bytes reported.  Every fused
schedule's LM row additionally gets an ``executor="mpmd"`` A/B row: the
same plan lowered to per-rank specialized programs (``plan.specialize``)
with the chain ``ppermute`` double-buffered one tick ahead —
bitwise-identical results (tests/test_schedule_exec.py, which also covers
the portal/U-Net models under mpmd; the unet-portal rows here are
measured spmd-only), so the row reports the perf story: the
overlapped-comm device model and the per-rank declared buffer bytes.
Per row:

* ``us_per_step`` — measured wall-clock per train step.  This container
  timeshares every "device" over the same host cores, so wall-clock tracks
  TOTAL executed work plus per-tick overhead — it is the honest
  executor-overhead regression metric, but it cannot exhibit the
  critical-path speedup a schedule buys on dedicated devices
  (benchmarks/util.py documents the same convention for the paper tables).
* ``us_per_step_device_model`` — event-driven critical path of the task
  table on ``pipe`` DEDICATED devices (schedules.simulate_device_times),
  with per-task costs calibrated from a MEASURED single-device sequential
  step of the same model, plus a chain-hop comm term (``COMM_UNITS``
  stage-forward units per cross-rank boundary hop).  Under
  ``executor="spmd"`` the hop serializes after the producing task; under
  ``"mpmd"`` the double-buffered send overlaps the next tick's compute —
  so the mpmd model is <= the spmd model for every table, and the delta
  is exactly the comm the overlap hides.
* ``bubble_fraction_theoretical`` — idle (rank, tick) slots in the table.
* ``bubble_fraction_measured`` — cost-weighted idle share of the
  calibrated device-model critical path.
* ``speedup_vs_gpipe`` — gpipe_tasked's device-model step time over this
  row's: "did the schedule pay off" at a glance.
* ``per_stage_stash`` / ``per_stage_activation_bytes`` — the DONATED park
  buffer per rank (arrival buffer == stash, see repro.core.plan): the true
  per-device activation footprint, non-uniform across stages (1F1B's
  stage 0 parks nothing — its input is re-gathered from the micro-batch
  buffer).  ``stash_bound`` keeps the schedule-level ``min(n - j, m)`` /
  ``m`` bound for comparison with the paper; ``park_depth`` is the
  uniform SPMD buffer depth the compiled program allocates.  MPMD rows
  additionally carry ``per_rank_buffer_bytes`` — what each rank's
  SPECIALIZED program declares (park + backward inbox + residual slots,
  from ``plan.specialize``) — next to
  ``uniform_max_buffer_bytes_per_rank``, the flattened SPMD allocation;
  rank 0 under 1f1b/zb sits strictly below the uniform max.

Two model families cover the unified runtime's surface: the plain LM path
and a U-Net-style portal model (cross-stage skip edges lowered to plan
routes), so the bench trajectory breaks if either regresses.  The portal
rows carry the same device-model columns (calibrated against their own
measured gpipe_tasked wall), so smoke tripwires can compare against full
runs.

Wire engineering (PR 7) columns ride on every fused row:
``wire_bytes_per_tick`` / ``wire_bytes_per_step`` — actual bytes the
executor's collectives carry per tick/step under the row's codec —
``wire_ratio`` (encoded / fp32 bytes) and ``overlapped_route_hops`` (the
count certified by ``plan.assert_route_overlap``: every route hop latches
one tick before it ships, so none can serialize under mpmd).  Dedicated
``model="lm-wire"`` rows A/B the codec grid (fp32 / bf16 / int8-ef on
both executors): the lossless fp32 rows must be BITWISE equal to the
spmd baseline loss curve, the lossy rows must track it within tolerance
while still training.

``--smoke`` runs a tiny grid and fails if any fused schedule's wall-clock
exceeds its overhead cap vs gpipe_tasked, if zb-reuse's device model
exceeds zb-recompute's, if any schedule's mpmd device model exceeds its
spmd device model, or if any wire tripwire above trips — the CI
tripwires for executor regressions.
"""
import json
import os
import sys

from benchmarks.util import run_with_devices

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_schedules.json")

BENCH = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.core import plan as plan_lib
from repro.core import schedules as S
from repro.launch import mesh as mesh_lib, steps
from repro.launch import sharding as sharding_lib
from repro.models.lm import LMModel
from repro.models import pipeline_hetero as PH
from repro.models.unet import UNetConfig, UNetModel
from repro.core import wire as wire_lib
from repro.optim import optimizers as optim

SMOKE = {smoke}
arch = configs.smoke_arch("smollm-360m")
shape = ShapeConfig("t", seq_len={seq}, global_batch={batch}, kind="train")
key = jax.random.PRNGKey(0)
rows = []

FUSED = ("gpipe_tasked", "1f1b", "interleaved:2", "zb", "zb-reuse")
SCHEDULES = FUSED if SMOKE else ("gpipe",) + FUSED
# chain-hop comm price, in stage-forward units: one boundary activation
# over an ICI-class link vs one stage forward of compute — a fixed
# TPU-flavoured ratio (the smoke model's own arithmetic intensity is too
# low to calibrate it honestly on CPU).  Reported per row so the A/B
# delta (what the mpmd overlap hides) is auditable.
COMM_UNITS = 0.1

def variant(name):
    # bench row name -> (schedule, residuals, remat).  zb-reuse pairs the
    # dots policy with residual reuse: the stash holds matmul outputs and
    # Bw recomputes only elementwise ops (bitwise vs recompute-zb).
    if name == "zb-reuse":
        return "zb", "reuse", "dots"
    return name, "recompute", "full"

def stash_report(name, pipe, m, carry_bytes, resid_info=None,
                 executor="spmd"):
    if name == "gpipe":
        # autodiff keeps every micro's boundary input alive as a residual
        return dict(park_depth=m, per_stage_stash=[m] * pipe,
                    stash_bound=[m] * pipe,
                    per_stage_activation_bytes=[m * carry_bytes] * pipe,
                    carry_bytes_per_micro=carry_bytes, residuals="autodiff")
    schedule, residuals, _ = variant(name)
    tplan = plan_lib.plan_for(schedule, m, pipe, residuals=residuals)
    bps = (resid_info or {{}}).get("resid_bytes_per_slot", 0)
    out = dict(park_depth=tplan.park_depth,
               per_stage_stash=list(tplan.per_stage_park),
               stash_bound=list(tplan.per_stage_stash),
               per_stage_activation_bytes=[d * carry_bytes
                                           for d in tplan.per_stage_park],
               carry_bytes_per_micro=carry_bytes,
               residuals=tplan.residuals,
               resid_slots=list(tplan.per_stage_resid),
               resid_depth=tplan.resid_depth,
               residual_bytes_per_slot=bps,
               residual_stash_bytes=[s * bps
                                     for s in tplan.per_stage_resid])
    if executor == "mpmd":
        # what each rank's SPECIALIZED program declares, vs the flattened
        # SPMD allocation (one executable must carry the ring max)
        out.update(sharding_lib.per_rank_buffer_bytes(tplan, carry_bytes,
                                                      bps))
    return out

def wire_cols(name, pipe, m, carry_bytes, wire="fp32", skips=()):
    # byte-priced wire traffic of the lowered plan, plus the plan-level
    # tripwire: assert_route_overlap proves every route hop has its
    # one-tick-earlier latch column, so under mpmd no hop can serialize
    # after its producing task.
    if name == "gpipe":
        return {{}}
    schedule, residuals, _ = variant(name)
    tplan = plan_lib.plan_for(schedule, m, pipe, residuals=residuals,
                              skips=skips, wire=wire)
    n_hops = plan_lib.assert_route_overlap(tplan)
    rep = wire_lib.plan_wire_report(tplan, carry_bytes)
    return dict(wire=rep["wire"],
                wire_bytes_per_tick=round(rep["bytes_per_tick"], 1),
                wire_bytes_per_step=round(rep["bytes_per_step"], 1),
                wire_ratio=round(rep["ratio"], 4),
                overlapped_route_hops=n_hops)

def schedule_model(name, pipe, m, unit_us, executor="spmd"):
    schedule, residuals, remat = variant(name)
    table, n_stages, ranks = plan_lib.schedule_table(schedule, m, pipe)
    cost = S.default_task_cost(n_stages, ranks, residuals=residuals,
                               remat=remat)
    t_end, busy = S.simulate_device_times(table, ranks, cost,
                                          comm_cost=COMM_UNITS,
                                          overlap_comm=executor == "mpmd")
    return dict(
        bubble_fraction_theoretical=round(S.bubble_fraction(table,
                                                            ranks=ranks), 4),
        bubble_fraction_measured=round(
            1.0 - sum(busy) / (ranks * t_end), 4) if t_end else 0.0,
        us_per_step_device_model=round(t_end * unit_us, 1),
        comm_cost_units=COMM_UNITS)

def time_step(step, *args):
    out = step(*args)                      # compile + warm
    jax.block_until_ready(jax.tree.leaves(out)[0])
    iters = 3 if SMOKE else 5
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = step(*args)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)   # min: noise-robust
    return best, out

def lm_build(name, pipe, m, executor="spmd", wire="fp32"):
    schedule, residuals, remat = variant(name)
    pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                          remat=remat, schedule=schedule,
                          residuals=residuals, executor=executor,
                          wire=wire)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(key)
    ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    opt = optim.init(ocfg, params)
    batch = {{k: jax.random.randint(key, v.shape, 0, arch.vocab)
             for k, v in model.input_specs(shape).items()}}
    resid_info = {{}}
    with set_mesh(mesh):
        step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape,
                                              ocfg, resid_info=resid_info))
        out = step(params, opt, batch)       # compile + warm
        jax.block_until_ready(jax.tree.leaves(out)[0])
    return step, params, opt, batch, mesh, float(out[2]["loss"]), resid_info

def lm_step_time(name, pipe, m):
    step, params, opt, batch, mesh, loss, _ = lm_build(name, pipe, m)
    with set_mesh(mesh):
        dt, _ = time_step(step, params, opt, batch)
    return dt, loss

EXECUTORS = ("spmd", "mpmd")

for pipe, m in {grid}:
    # calibrate the device-model unit: one MEASURED sequential step
    # (pipe=1, fused executor) = m micros x (F + fused B = 4) model-forward
    # units of real compute on this machine.
    t_seq, _ = lm_step_time("gpipe_tasked", 1, m)
    unit_us = t_seq * 1e6 / (4 * m)
    # compile every schedule x executor first, then time ROUND-ROBIN
    # (paired min-of-rounds): schedule-vs-schedule wall ratios on a
    # timeshared host are noise-dominated unless measured back-to-back.
    keys = [(s, e) for s in SCHEDULES
            for e in (EXECUTORS if s != "gpipe" else ("spmd",))]
    built = {{k: lm_build(k[0], pipe, m, executor=k[1]) for k in keys}}
    walls = {{k: float("inf") for k in keys}}
    rounds = 2 if SMOKE else 4
    for _ in range(rounds):
        for k in keys:
            step, params, opt, batch, mesh = built[k][:5]
            with set_mesh(mesh):
                dt, _ = time_step(step, params, opt, batch)
            walls[k] = min(walls[k], dt)
    base_model_us = None
    for name, executor in keys:
        mbg = shape.global_batch // m
        carry_bytes = mbg * shape.seq_len * arch.d_model * 4  # f32 boundary
        model_cols = schedule_model(name, pipe, m, unit_us, executor)
        if (name, executor) == ("gpipe_tasked", "spmd"):
            base_model_us = model_cols["us_per_step_device_model"]
        # the loss is executor- and schedule-invariant (bitwise contract)
        rows.append(dict(
            model="lm", schedule=name, pipe=pipe, n_micro=m,
            executor=executor,
            us_per_step=round(walls[(name, executor)] * 1e6, 1),
            us_per_step_sequential=round(t_seq * 1e6, 1),
            loss=built[(name, executor)][5], **model_cols,
            **wire_cols(name, pipe, m, carry_bytes),
            **stash_report(name, pipe, m, carry_bytes,
                           resid_info=built[(name, executor)][6],
                           executor=executor)))
    del built
    for r in rows:
        if r["model"] == "lm" and r["pipe"] == pipe and r["n_micro"] == m:
            r["speedup_vs_gpipe"] = round(
                base_model_us / r["us_per_step_device_model"], 3)

# --- portal-model variant: U-Net skips through the unified runtime -------
if not SMOKE:
    ucfg = UNetConfig(B=1, C=8, levels=4, img=32)
    UB = 8
    x = jax.random.normal(jax.random.PRNGKey(1), (UB, ucfg.img, ucfg.img, 3))
    for pipe, m in [(4, 4)]:
        losses = {{}}
        urows = []
        for name in FUSED:
            schedule, residuals, remat = variant(name)
            pcfg = ParallelConfig(pipe=pipe, tp=1, data=2, pod=1, n_micro=m,
                                  portals=True, remat=remat,
                                  schedule=schedule, residuals=residuals)
            mesh = mesh_lib.make_smoke_mesh(pcfg)
            umodel = UNetModel(ucfg, pipe * pcfg.virtual_stages)
            uparams = umodel.init(jax.random.PRNGKey(0))
            prog = PH.build_hetero_program(umodel, uparams, UB // m, pcfg,
                                           x[:2])
            carry_bytes = (UB // m) * prog.carry_proto["buf"].shape[1] * 4
            resid_info = {{}}
            with set_mesh(mesh):
                tgt = jnp.zeros((UB,) + tuple(prog.out_proto.shape[1:]),
                                jnp.float32)
                call = jax.jit(PH.hetero_grad_call(prog, mesh, pcfg,
                                                   resid_info=resid_info))
                dt, (loss, _) = time_step(call, prog.stacked_params, x, tgt)
            losses[name] = float(loss)
            urows.append(dict(
                model="unet-portal", schedule=name, pipe=pipe, n_micro=m,
                executor="spmd", n_skip_edges=len(prog.skips),
                us_per_step=round(dt * 1e6, 1), loss=float(loss),
                **wire_cols(name, pipe, m, carry_bytes,
                            skips=prog.skips),
                **stash_report(name, pipe, m, carry_bytes,
                               resid_info=resid_info)))
        # device-model columns for the portal rows, calibrated against the
        # measured gpipe_tasked wall (no single-device portal run exists):
        # unit_us = wall(gpipe_tasked) / t_end_model(gpipe_tasked), so the
        # gpipe_tasked row's model time equals its wall by construction
        # and the other rows scale by the table critical path.  The
        # uniform-stage cost model approximates the hetero stage split.
        base_tbl, base_n, base_r = plan_lib.schedule_table("gpipe_tasked",
                                                           m, pipe)
        t_base, _ = S.simulate_device_times(
            base_tbl, base_r, S.default_task_cost(base_n, base_r),
            comm_cost=COMM_UNITS)
        u_unit = [r for r in urows
                  if r["schedule"] == "gpipe_tasked"][0]["us_per_step"] \
            / t_base
        for r in urows:
            r.update(schedule_model(r["schedule"], pipe, m, u_unit))
        rows.extend(urows)
        # the unified runtime's contract: schedules are the same computation
        assert len(set(losses.values())) == 1, losses

# --- wire tripwires: the codec on the real executor (smoke AND full) -----
# fp32 is the lossless mode: its identity codec plus the double-buffered
# route latches must not perturb a single bit, so both executors' 5-step
# loss curves must be BITWISE equal to the spmd baseline (the pre-codec
# PR 6 path computes exactly this curve).  Lossy codecs must track the
# fp32 curve (int8-ef's error feedback keeps the drift bounded) and still
# train.  Each codec row lands in the JSON with its on-the-wire bytes per
# tick and compressed/uncompressed ratio.
wp, wm = {grid}[0]

def wire_curve(executor, wire, n_steps=5):
    step, params, opt, batch, mesh, _, _ = lm_build(
        "1f1b", wp, wm, executor=executor, wire=wire)
    ls = []
    with set_mesh(mesh):
        p, o = params, opt
        for _ in range(n_steps):
            p, o, aux = step(p, o, batch)
            ls.append(float(aux["loss"]))
    return ls

base_curve = wire_curve("spmd", "fp32")
w_carry = (shape.global_batch // wm) * shape.seq_len * arch.d_model * 4
for executor in ("spmd", "mpmd"):
    for wname in ("fp32", "bf16", "int8-ef"):
        cur = wire_curve(executor, wname)
        if wname == "fp32":
            assert cur == base_curve, (executor, wname, cur, base_curve)
        else:
            assert all(abs(a - b) <= 0.05 * abs(b) + 1e-6
                       for a, b in zip(cur, base_curve)), \\
                (executor, wname, cur, base_curve)
            assert cur[-1] < cur[0], (executor, wname, cur)
        rows.append(dict(model="lm-wire", schedule="1f1b", pipe=wp,
                         n_micro=wm, executor=executor,
                         loss_curve=[round(l, 6) for l in cur],
                         **wire_cols("1f1b", wp, wm, w_carry, wire=wname)))

print("JSON" + json.dumps(rows))
"""


def main(grid=((2, 4), (4, 4), (4, 8)), batch=16, seq=32, n_devices=8,
         smoke=False):
    if smoke:
        grid, batch, seq = ((2, 4),), 8, 16
    out = run_with_devices(
        BENCH.format(grid=tuple(grid), batch=batch, seq=seq,
                     smoke=repr(smoke)),
        n_devices=n_devices, timeout=5400)
    rows = json.loads(out.split("JSON", 1)[1])
    for r in rows:
        if r["model"] == "lm-wire":
            # codec A/B rows carry loss curves + wire bytes, not wall time
            print(f"wire_{r['schedule']}_p{r['pipe']}_m{r['n_micro']}"
                  f"_{r['executor']}_{r['wire']},"
                  f"{r['wire_bytes_per_tick']},ratio={r['wire_ratio']}")
            continue
        extra = ""
        if "us_per_step_device_model" in r:
            extra = (f",model={r['us_per_step_device_model']}"
                     f",bubble={r['bubble_fraction_theoretical']}")
        print(f"schedule_{r['model']}_{r['schedule']}_p{r['pipe']}"
              f"_m{r['n_micro']}_{r.get('executor', 'spmd')},"
              f"{r['us_per_step']}{extra}")

    by_key = {(r["model"], r["pipe"], r["n_micro"], r["schedule"],
               r.get("executor", "spmd")): r for r in rows}
    for (model, pipe, m, s, ex), r in by_key.items():
        g = by_key.get((model, pipe, m, "gpipe_tasked", "spmd"))
        if g is None:
            continue
        if s == "1f1b":
            # the donated stash is non-uniform: stage 0 parks nothing (its
            # input is re-gathered), later stages stay within the paper
            # bound (+1 in-flight arrival) and under GPipe's footprint
            assert r["per_stage_stash"][0] == 0
            assert len(set(r["per_stage_stash"])) > 1 or pipe == 1
            assert all(a <= b + 1 for a, b in zip(r["per_stage_stash"],
                                                  r["stash_bound"]))
            assert r["stash_bound"] == [min(pipe - j, m)
                                        for j in range(pipe)]
            assert sum(r["per_stage_activation_bytes"]) \
                <= sum(g["per_stage_activation_bytes"])
        if smoke and s in ("1f1b", "interleaved:2", "zb", "zb-reuse"):
            # CI tripwire: fused-executor overhead must stay bounded.  At
            # the smoke shape compute is negligible, so interleaved pays
            # its v-fold branch-dispatch overhead in full — it gets a
            # proportionally wider bound; so does the mpmd lowering, whose
            # R-way rank switch adds pure dispatch (never compute) at this
            # degenerate scale.  spmd rows must stay within 1.5x.
            cap = 2.5 if (s.startswith("interleaved") or ex == "mpmd") \
                else 1.5
            assert r["us_per_step"] <= cap * g["us_per_step"], \
                (s, ex, r["us_per_step"], g["us_per_step"], cap)

    # residual-reuse tripwire (smoke AND full): dropping Bw's recompute
    # must shorten the zb dedicated-device step, and the reuse row must
    # actually carry a residual stash.
    for (model, pipe, m, s, ex), r in by_key.items():
        if s != "zb-reuse" or model != "lm":
            continue
        z = by_key[(model, pipe, m, "zb", ex)]
        assert r["us_per_step_device_model"] <= z["us_per_step_device_model"], \
            (pipe, m, ex, r["us_per_step_device_model"],
             z["us_per_step_device_model"])
        assert r["residuals"] == "reuse" and sum(r["resid_slots"]) > 0
        assert sum(r["residual_stash_bytes"]) > 0, r["residual_bytes_per_slot"]

    # wire tripwires (smoke AND full): every fused plan passed the
    # in-bench assert_route_overlap latch check (column present); default
    # rows ship fp32 (ratio 1.0) with real bytes on the wire; the codec
    # A/B rows' compressed/uncompressed ratios match their bytes factors
    # (bf16 halves the wire, int8-ef lands near 0.25 + per-block scales).
    for r in rows:
        if "wire_ratio" not in r:
            assert r["schedule"] == "gpipe", r["schedule"]
            continue
        assert r["wire_bytes_per_tick"] > 0, r
        if r["model"] == "lm-wire":
            want = {"fp32": 1.0, "bf16": 0.5}.get(r["wire"])
            if want is not None:
                assert abs(r["wire_ratio"] - want) < 1e-6, r
            else:
                assert 0.2 < r["wire_ratio"] < 0.3, r
        else:
            assert r["wire"] == "fp32" and r["wire_ratio"] == 1.0, r

    # executor A/B tripwires (smoke AND full):
    #  * the mpmd (comm-overlapped) device model must be <= spmd for EVERY
    #    fused schedule — the double buffering can only hide comm;
    #  * mpmd rows declare per-rank buffer bytes strictly below the
    #    uniform SPMD max for at least one rank (1f1b/zb: rank 0 parks 0).
    for (model, pipe, m, s, ex), r in by_key.items():
        if model != "lm" or ex != "mpmd":
            continue
        sp = by_key[(model, pipe, m, s, "spmd")]
        assert r["us_per_step_device_model"] <= \
            sp["us_per_step_device_model"], \
            (s, pipe, m, r["us_per_step_device_model"],
             sp["us_per_step_device_model"])
        if s in ("1f1b", "zb", "zb-reuse") and pipe > 1:
            uni = r["uniform_max_buffer_bytes_per_rank"]
            assert any(b < uni for b in r["per_rank_buffer_bytes"]), \
                (s, pipe, m, r["per_rank_buffer_bytes"], uni)

    if smoke:
        print("# smoke OK (fused schedules within their overhead caps; "
              "zb-reuse device model <= zb-recompute; mpmd device model "
              "<= spmd with per-rank buffers below uniform max; route "
              "latches verified and wire codecs bitwise/tolerance-checked)")
        return rows

    # schedule-payoff acceptance: on dedicated devices, interleaving and/or
    # split backward must strictly undercut plain 1F1B at pipe=4
    for m in (4, 8):
        f = by_key.get(("lm", 4, m, "1f1b", "spmd"))
        if f is None:
            continue
        better = [s for s in ("interleaved:2", "zb", "zb-reuse")
                  if ("lm", 4, m, s, "spmd") in by_key
                  and by_key[("lm", 4, m, s, "spmd")]["us_per_step_device_model"]
                  < f["us_per_step_device_model"]]
        assert better, f"no schedule beats 1f1b at pipe=4, m={m}"
    report = {"bench": "schedules", "arch": "smollm-360m(smoke)+unet(smoke)",
              "rows": rows}
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT}")
    return report


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
