"""Schedule A/B benchmark: GPipe vs 1F1B step time + peak activation bytes.

Runs the fused scheduler (``schedule="gpipe_tasked"`` vs ``"1f1b"``) and the
legacy autodiff path (``"gpipe"``) on real multi-device pipelines (XLA host
devices, reduced model — CPU is the runtime, TPU the target) and emits a
machine-readable ``BENCH_schedules.json`` so the perf trajectory has a
baseline:

* ``us_per_step`` — measured wall-clock per train step (single physical
  core: pipeline parallelism cannot show wall-clock speedup here; the
  numbers baseline *relative* schedule cost, not hardware throughput).
* ``stash_depth`` / ``per_stage_stash`` — the plan-derived activation stash
  (number of live micro-batch boundary activations per stage).
* ``peak_activation_bytes`` — stash_depth x bytes(one boundary activation),
  the structural per-device stash footprint.  1F1B's bound is
  ``min(n - j, m)`` vs GPipe's ``m`` (paper §2.1's motivation, realized
  beyond-paper).
"""
import json
import os

from benchmarks.util import run_with_devices

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_schedules.json")

BENCH = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.core import plan as plan_lib
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
shape = ShapeConfig("t", seq_len=32, global_batch={batch}, kind="train")
key = jax.random.PRNGKey(0)
rows = []
for pipe, m in {grid}:
    for schedule in ("gpipe", "gpipe_tasked", "1f1b"):
        pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                              remat="full", schedule=schedule)
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        model = LMModel(arch, pcfg, dtype=jnp.float32)
        params = model.init(key)
        ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=8)
        opt = optim.init(ocfg, params)
        batch = {{k: jax.random.randint(key, v.shape, 0, arch.vocab)
                 for k, v in model.input_specs(shape).items()}}
        mbg = shape.global_batch // m
        carry_bytes = mbg * shape.seq_len * arch.d_model * 4   # f32 boundary
        if schedule == "gpipe":
            depth, per_stage = m, [m] * pipe   # autodiff stashes every micro
        else:
            tplan = plan_lib.plan_for(schedule, m, pipe)
            depth, per_stage = tplan.stash_depth, list(tplan.per_stage_stash)
        with set_mesh(mesh):
            step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape,
                                                  ocfg))
            p, o, mt = step(params, opt, batch)      # compile + warm
            jax.block_until_ready(mt["loss"])
            iters = 3
            t0 = time.perf_counter()
            for _ in range(iters):
                p, o, mt = step(p, o, batch)
            jax.block_until_ready(mt["loss"])
            dt = (time.perf_counter() - t0) / iters
        rows.append(dict(
            schedule=schedule, pipe=pipe, n_micro=m,
            us_per_step=round(dt * 1e6, 1),
            loss=float(mt["loss"]),
            stash_depth=depth, per_stage_stash=per_stage,
            peak_activation_bytes=depth * carry_bytes,
            carry_bytes_per_micro=carry_bytes))
print("JSON" + json.dumps(rows))
"""


def main(grid=((2, 4), (4, 8)), batch=16, n_devices=8):
    out = run_with_devices(BENCH.format(grid=tuple(grid), batch=batch),
                           n_devices=n_devices, timeout=2400)
    rows = json.loads(out.split("JSON", 1)[1])
    report = {"bench": "schedules", "arch": "smollm-360m(smoke)",
              "rows": rows}
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"schedule_{r['schedule']}_p{r['pipe']}_m{r['n_micro']},"
              f"{r['us_per_step']},stash={r['stash_depth']}"
              f",act_bytes={r['peak_activation_bytes']}")
    # sanity: the 1F1B memory bound must hold in every emitted row
    by_key = {(r["pipe"], r["n_micro"], r["schedule"]): r for r in rows}
    for (pipe, m, s), r in by_key.items():
        if s == "1f1b":
            g = by_key[(pipe, m, "gpipe_tasked")]
            assert r["peak_activation_bytes"] <= g["peak_activation_bytes"]
            assert all(r["per_stage_stash"][j] <= min(pipe - j, m)
                       for j in range(pipe))
    print(f"# wrote {OUT}")
    return report


if __name__ == "__main__":
    main()
