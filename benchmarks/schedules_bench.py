"""Schedule A/B benchmark: GPipe vs 1F1B step time + peak activation bytes.

Runs the fused scheduler (``schedule="gpipe_tasked"`` vs ``"1f1b"``) and the
legacy-semantics autodiff path (``"gpipe"``, the forward-only plan through
the same executor) on real multi-device pipelines (XLA host devices,
reduced model — CPU is the runtime, TPU the target) and emits a
machine-readable ``BENCH_schedules.json`` so the perf trajectory has a
baseline:

* ``us_per_step`` — measured wall-clock per train step (single physical
  core: pipeline parallelism cannot show wall-clock speedup here; the
  numbers baseline *relative* schedule cost, not hardware throughput).
* ``stash_depth`` / ``per_stage_stash`` — the plan-derived activation stash
  (number of live micro-batch boundary activations per stage).
* ``per_stage_activation_bytes`` — the TRUE per-stage stash footprint
  (``per_stage_stash[j] x bytes(one boundary activation)``), what a
  per-device allocator charges stage ``j``; 1F1B's bound is
  ``min(n - j, m)`` vs GPipe's ``m`` (paper §2.1's motivation, realized
  beyond-paper).  ``peak_activation_bytes`` is the flattened SPMD max over
  stages (the uniform buffer the compiled program allocates today).

Two model families cover the unified runtime's surface: the plain LM path
and a U-Net-style portal model (cross-stage skip edges lowered to plan
routes), so the bench trajectory breaks if either regresses.
"""
import json
import os

from benchmarks.util import run_with_devices

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_schedules.json")

BENCH = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import set_mesh
from repro.configs.base import ShapeConfig, ParallelConfig
from repro.core import plan as plan_lib
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.models import pipeline_hetero as PH
from repro.models.unet import UNetConfig, UNetModel
from repro.optim import optimizers as optim

arch = configs.smoke_arch("smollm-360m")
shape = ShapeConfig("t", seq_len=32, global_batch={batch}, kind="train")
key = jax.random.PRNGKey(0)
rows = []

def stash_report(schedule, pipe, m, carry_bytes):
    if schedule == "gpipe":
        depth, per_stage = m, [m] * pipe   # autodiff stashes every micro
    else:
        tplan = plan_lib.plan_for(schedule, m, pipe)
        depth, per_stage = tplan.stash_depth, list(tplan.per_stage_stash)
    return dict(stash_depth=depth, per_stage_stash=per_stage,
                peak_activation_bytes=depth * carry_bytes,
                per_stage_activation_bytes=[d * carry_bytes
                                            for d in per_stage],
                carry_bytes_per_micro=carry_bytes)

def time_step(step, *args):
    out = step(*args)                      # compile + warm
    jax.block_until_ready(jax.tree.leaves(out)[0])
    iters = 3
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.perf_counter() - t0) / iters, out

for pipe, m in {grid}:
    for schedule in ("gpipe", "gpipe_tasked", "1f1b"):
        pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m,
                              remat="full", schedule=schedule)
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        model = LMModel(arch, pcfg, dtype=jnp.float32)
        params = model.init(key)
        ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=8)
        opt = optim.init(ocfg, params)
        batch = {{k: jax.random.randint(key, v.shape, 0, arch.vocab)
                 for k, v in model.input_specs(shape).items()}}
        mbg = shape.global_batch // m
        carry_bytes = mbg * shape.seq_len * arch.d_model * 4   # f32 boundary
        with set_mesh(mesh):
            step = jax.jit(steps.build_train_step(model, pcfg, mesh, shape,
                                                  ocfg))
            dt, (p, o, mt) = time_step(step, params, opt, batch)
        rows.append(dict(
            model="lm", schedule=schedule, pipe=pipe, n_micro=m,
            us_per_step=round(dt * 1e6, 1), loss=float(mt["loss"]),
            **stash_report(schedule, pipe, m, carry_bytes)))

# --- portal-model variant: U-Net skips through the unified runtime -------
ucfg = UNetConfig(B=1, C=8, levels=4, img=32)
UB = 8
x = jax.random.normal(jax.random.PRNGKey(1), (UB, ucfg.img, ucfg.img, 3))
for pipe, m in [(4, 4)]:
    losses = {{}}
    for schedule in ("gpipe_tasked", "1f1b"):
        pcfg = ParallelConfig(pipe=pipe, tp=1, data=2, pod=1, n_micro=m,
                              portals=True, remat="full", schedule=schedule)
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        umodel = UNetModel(ucfg, pcfg.pipe)
        uparams = umodel.init(jax.random.PRNGKey(0))
        prog = PH.build_hetero_program(umodel, uparams, UB // m, pcfg, x[:2])
        carry_bytes = (UB // m) * prog.carry_proto["buf"].shape[1] * 4
        with set_mesh(mesh):
            tgt = jnp.zeros((UB,) + tuple(prog.out_proto.shape[1:]),
                            jnp.float32)
            call = jax.jit(PH.hetero_grad_call(prog, mesh, pcfg))
            dt, (loss, _) = time_step(call, prog.stacked_params, x, tgt)
        losses[schedule] = float(loss)
        rows.append(dict(
            model="unet-portal", schedule=schedule, pipe=pipe, n_micro=m,
            n_skip_edges=len(prog.skips),
            us_per_step=round(dt * 1e6, 1), loss=float(loss),
            **stash_report(schedule, pipe, m, carry_bytes)))
    # the unified runtime's contract: schedules are the same computation
    assert losses["gpipe_tasked"] == losses["1f1b"], losses
print("JSON" + json.dumps(rows))
"""


def main(grid=((2, 4), (4, 8)), batch=16, n_devices=8):
    out = run_with_devices(BENCH.format(grid=tuple(grid), batch=batch),
                           n_devices=n_devices, timeout=2400)
    rows = json.loads(out.split("JSON", 1)[1])
    report = {"bench": "schedules", "arch": "smollm-360m(smoke)+unet(smoke)",
              "rows": rows}
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"schedule_{r['model']}_{r['schedule']}_p{r['pipe']}"
              f"_m{r['n_micro']},{r['us_per_step']},stash={r['stash_depth']}"
              f",act_bytes={r['peak_activation_bytes']}")
    # sanity: the 1F1B memory bound must hold PER STAGE in every row
    by_key = {(r["model"], r["pipe"], r["n_micro"], r["schedule"]): r
              for r in rows}
    for (model, pipe, m, s), r in by_key.items():
        if s == "1f1b":
            g = by_key[(model, pipe, m, "gpipe_tasked")]
            assert r["per_stage_stash"] \
                == [min(pipe - j, m) for j in range(pipe)]
            assert all(a <= b for a, b in
                       zip(r["per_stage_activation_bytes"],
                           g["per_stage_activation_bytes"]))
    print(f"# wrote {OUT}")
    return report


if __name__ == "__main__":
    main()
