"""Paper Table 4 analogue: U-Net throughput vs pipeline width.

Naive-1 = no pipeline, no checkpointing (single device); Pipeline-n =
torchgpipe-style with n stages, batch/m chosen per column as in the paper.
Scaled-down (B, C) and image for host-device execution; the trend (single-
stage pipelining costs ~15%, wider pipelines win) is the reproduction
target, exact numbers are hardware-specific.
"""
import json

BENCH = """
import time, json, sys, types
import jax, jax.numpy as jnp
_m = types.ModuleType("benchmarks_schedule_model")
def _schedule_time(costs, sizes, m, remat=True):
    # per-SAMPLE critical path: ticks (m+n-1) x per-sample tick cost
    # (fwd max-stage + bwd max-stage x (2 + recompute)), amortized over m.
    bounds = [0]
    for s in sizes: bounds.append(bounds[-1] + s)
    stage = [sum(costs[bounds[j]:bounds[j+1]]) for j in range(len(sizes))]
    nn = len([s for s in sizes if s > 0])
    per_tick = max(stage) * (1.0 + (3.0 if remat else 2.0))
    return (m + nn - 1) / m * per_tick
def _sequential_time(costs, m):
    return sum(costs) * 3.0   # per sample, fwd + bwd, no recompute
_m.schedule_time = _schedule_time
_m.sequential_time = _sequential_time
sys.modules["benchmarks_schedule_model"] = _m
from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models.unet import UNetConfig, UNetModel
from repro.models import pipeline_hetero as PH

cfg = UNetConfig(B={B}, C={C}, levels=4, img={img})
n, m, B_GLOBAL = {n}, {m}, {batch}
remat = "none" if n == 0 else "full"
pipe = max(n, 1)
pcfg = ParallelConfig(pipe=pipe, tp=1, data=1, pod=1, n_micro=m, remat=remat)
mesh = mesh_lib.make_smoke_mesh(pcfg)
model = UNetModel(cfg, pcfg.pipe)
params = model.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (B_GLOBAL, cfg.img, cfg.img, 3))
y = jax.random.normal(jax.random.PRNGKey(2), (B_GLOBAL, cfg.img, cfg.img, 1))
prog = PH.build_hetero_program(model, params, B_GLOBAL // m, pcfg, x[:2])
with set_mesh(mesh):
    def loss(p, xx, yy):
        prog2 = PH.HeteroProgram(p, prog.stage_apply, prog.carry_proto,
                                 prog.skips, prog.skip_protos, prog.out_proto)
        out = PH.hetero_forward(prog2, mesh, pcfg, xx)
        return jnp.mean((out - yy) ** 2)
    step = jax.jit(jax.grad(loss))
    g = step(prog.stacked_params, x, y)
    jax.block_until_ready(g)
    t0 = time.perf_counter()
    for _ in range(3):
        g = step(prog.stacked_params, x, y)
    jax.block_until_ready(g)
    dt = (time.perf_counter() - t0) / 3
from benchmarks_schedule_model import schedule_time, sequential_time
costs = [l.flops() for l in model.layers]
pred = (sequential_time(costs, m) if {n} == 0
        else schedule_time(costs, model.sizes, m))
print("RESULT " + json.dumps(dict(n={n}, m=m, samples_per_s=B_GLOBAL/dt,
                                  step_s=dt, pred_t=pred)))
"""

# (n, m, batch): n=0 encodes Naive-1 (no pipeline, no checkpointing)
COLUMNS = [(0, 1, 8), (1, 2, 16), (2, 8, 16), (4, 8, 16), (8, 16, 32)]


def run(B=1, C=8, img=64, columns=COLUMNS):
    from benchmarks.util import run_with_devices
    rows = []
    for n, m, batch in columns:
        txt = run_with_devices(
            BENCH.format(B=B, C=C, img=img, n=n, m=m, batch=batch),
            max(n, 2), timeout=2400)
        for line in txt.splitlines():
            if line.startswith("RESULT "):
                rows.append(json.loads(line[len("RESULT "):]))
    return rows


def main(columns=COLUMNS):
    rows = run(columns=columns)
    base = rows[0]["samples_per_s"]
    print("name,us_per_call,derived")
    for r in rows:
        tag = "naive-1" if r["n"] == 0 else f"pipeline-{r['n']}"
        basep = rows[0]["pred_t"]
        print(f"unet_speed/{tag},{r['step_s']*1e6:.0f},"
              f"measured_1core={r['samples_per_s']/base:.3f};"
              f"predicted_speedup={basep/r['pred_t']:.2f};m={r['m']}")


if __name__ == "__main__":
    main()
