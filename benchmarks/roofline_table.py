"""Assignment roofline table: per (arch x shape x mesh) terms from the
dry-run artifacts (launch/dryrun.py --all --out ...)."""
import json
import os

DEFAULT_PATHS = ["results/dryrun_sp.json", "results/dryrun_mp.json",
                 "/tmp/dryrun_sp.json", "/tmp/dryrun_mp.json"]


def load(paths=None):
    rows = []
    candidates = paths or DEFAULT_PATHS
    # prefer results/ artifacts; fall back to /tmp (no duplicates)
    chosen = [p for p in candidates[:2] if os.path.exists(p)] or \
             [p for p in candidates[2:] if os.path.exists(p)]
    for p in chosen:
        rows.extend(json.load(open(p)))
    return rows


def main():
    rows = load()
    if not rows:
        print("roofline/no_dryrun_artifacts_found,0,run launch.dryrun first")
        return
    print("name,us_per_call,derived")
    for r in rows:
        if r.get("skipped"):
            print(f"roofline/{r['arch']}/{r['shape']}/-,0,skipped:{r.get('reason','')[:40]}")
            continue
        if r.get("error"):
            print(f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh','?')},0,ERROR")
            continue
        step_us = max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},{step_us:.0f},"
              f"tc={r['t_compute']*1e3:.1f}ms;tm={r['t_memory']*1e3:.1f}ms;"
              f"tx={r['t_collective']*1e3:.1f}ms;bn={r['bottleneck']};"
              f"useful={r['useful_ratio']:.3f};"
              f"roofline={r['roofline_fraction']:.3f};"
              f"mem_gib={r['memory_per_device']/2**30:.1f}")


if __name__ == "__main__":
    main()
