"""Planner smoke tripwires (CI `planner-smoke` job).

Runs the planner over the three model families the bench suite measures —
LM (smollm smoke), encoder-decoder with portals (whisper smoke), and the
heterogeneous U-Net — at pipe in {2, 4}, and checks the two invariants the
hypothesis suite asserts statistically:

1. **Budget**: every plan the planner marks feasible (and in particular
   the chosen top plan) predicts peak per-rank memory within the
   ``hardware.yaml`` budget it was searched under.
2. **Dominance**: on every row of ``BENCH_schedules.json``, the planner's
   top choice has device-model step time <= the row's hand-picked config,
   both scored by the same device model.

Usage:  PYTHONPATH=src python -m repro.planner.smoke [--bench path]
"""
from __future__ import annotations

import argparse
import json
import os

from repro import configs
from repro.configs.base import PlanSpec, ScheduleSpec, ShapeConfig
from repro.planner.hardware import HardwareSpec
from repro.planner.search import (plan_profile, profile_arch, profile_unet,
                                  score_candidate)


def _profiles(global_batch: int):
    """The three smoke families, as planner profiles."""
    lm = profile_arch(configs.smoke_arch("smollm-360m"),
                      ShapeConfig("smoke", 128, global_batch, "train"))
    whisper = profile_arch(configs.smoke_arch("whisper-tiny"),
                           ShapeConfig("smoke", 64, global_batch, "train"))
    from repro.models.unet import UNetConfig
    unet = profile_unet(UNetConfig(B=1, C=4, levels=3, img=32), global_batch)
    return {"lm": lm, "whisper-portal": whisper, "unet": unet}


def check_budget(pipes=(2, 4), global_batch: int = 16) -> int:
    """Tripwire 1: feasible plans stay within their declared budget."""
    checked = 0
    for name, profile in _profiles(global_batch).items():
        for pipe in pipes:
            hw = HardwareSpec(name=f"smoke-{pipe}", ranks=pipe,
                              memory_bytes=2.0 * 2**30)
            report = plan_profile(profile, hw, shape_name="smoke")
            best = report.best
            assert best is not None, \
                f"{name}/pipe={pipe}: no feasible plan under 2 GiB/rank"
            for c in report.candidates:
                if c.feasible:
                    assert max(c.mem_bytes) <= hw.memory_bytes, (
                        f"{name}/pipe={pipe}: feasible plan "
                        f"{c.spec.to_dict()} predicts "
                        f"{max(c.mem_bytes)} B > budget {hw.memory_bytes} B")
                    checked += 1
            print(f"[planner-smoke] budget ok: {name} pipe={pipe} "
                  f"best={best.spec.schedule.name} m={best.spec.microbatches} "
                  f"peak={best.peak_mem_bytes / 2**20:.1f} MiB")
    return checked


def _row_spec(row: dict) -> PlanSpec:
    """A BENCH_schedules.json row's hand-picked config, as a PlanSpec."""
    schedule = row["schedule"]
    residuals = "recompute"
    if schedule == "zb-reuse":
        schedule, residuals = "zb", "reuse"
    elif schedule == "gpipe":
        schedule = "gpipe_tasked"     # same task table, same device model
    sched = ScheduleSpec.from_string(schedule, residuals=residuals,
                                     executor=row.get("executor", "spmd"))
    return PlanSpec(schedule=sched, pipe=int(row["pipe"]),
                    microbatches=int(row["n_micro"]))


def check_bench_dominance(bench_path: str, global_batch: int = 16) -> int:
    """Tripwire 2: planner top <= every hand-picked BENCH row, same scorer."""
    with open(bench_path) as f:
        rows = json.load(f)["rows"]
    profiles = _profiles(global_batch)
    hw_cache = {}
    checked = 0
    for row in rows:
        profile = profiles["lm" if row["model"] == "lm" else "unet"]
        pipe = int(row["pipe"])
        if global_batch % int(row["n_micro"]):
            continue
        key = (profile.name, pipe)
        if key not in hw_cache:
            hw = HardwareSpec(name=f"bench-{pipe}", ranks=pipe,
                              memory_bytes=64.0 * 2**30)
            hw_cache[key] = plan_profile(profile, hw, shape_name="bench")
        report = hw_cache[key]
        hw = HardwareSpec.from_dict(report.hardware)
        hand = score_candidate(profile, hw, _row_spec(row))
        top = report.best
        assert top is not None, f"no feasible plan for {row['model']}/{pipe}"
        assert top.step_s <= hand.step_s * (1 + 1e-9), (
            f"planner top ({top.spec.to_dict()}, {top.step_s:.6g}s) LOSES "
            f"to hand-picked row {row['schedule']}/m={row['n_micro']}"
            f"/pipe={pipe} ({hand.step_s:.6g}s)")
        checked += 1
    print(f"[planner-smoke] dominance ok on {checked} BENCH rows")
    return checked


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "BENCH_schedules.json"))
    args = ap.parse_args()
    n_budget = check_budget()
    n_rows = check_bench_dominance(args.bench)
    print(f"[planner-smoke] PASS ({n_budget} budget checks, "
          f"{n_rows} bench rows)")


if __name__ == "__main__":
    main()
