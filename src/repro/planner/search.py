"""Plan search: enumerate schedule x partition x microbatch, score, rank.

The search space is the cross product the README's manual recipe used to
hand-pick:

* microbatch count ``m`` — divisors of the global batch (GPipe wants
  ``m >> n``; more micros shrink the bubble but shrink the per-micro
  matmuls and grow the park);
* schedule — ``gpipe_tasked`` / ``1f1b`` / ``interleaved:v`` / ``zb``
  (the bitwise-verified zoo from ``core.schedules``);
* residual mode — ``recompute`` everywhere, ``reuse`` (true ZB-H1) for
  split-backward schedules;
* executor — ``spmd`` (serialized chain hop) / ``mpmd`` (double-buffered
  overlap);
* stage partition — legacy uniform ceil layout, or the exact contiguous
  minimax cuts of ``core.balance`` over per-layer flops / bytes.

Each point is scored with :func:`repro.core.plan.plan_cost`: the
event-driven device model (with the comm/overlap term priced from the
hardware spec, exactly like ``launch.dryrun``) gives the time objective;
the lowered plan's per-rank park / inbox / residual slot high-waters plus
hosted parameter bytes give the memory constraint checked against
``hardware.memory_bytes``.  Models enter through a light
:class:`ModelProfile` so transformer LMs (``profile_arch``) and the
heterogeneous U-Net (``profile_unet``) share one search path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import (ArchConfig, ParallelConfig, PlanSpec,
                                ScheduleSpec, ShapeConfig)
from repro.core import balance
from repro.core import plan as plan_lib
from repro.core import wire as wire_lib
from repro.core.stage import partition_layout
from repro.planner.hardware import HardwareSpec
from repro.planner.report import PlanCandidate, PlanReport


@dataclass(frozen=True)
class ModelProfile:
    """What the planner needs to know about a model, and nothing else."""
    name: str
    layer_flops: Tuple[float, ...]     # per-example FORWARD flops per layer
    layer_bytes: Tuple[int, ...]       # parameter bytes per layer
    carry_bytes_per_example: int       # stage-boundary activation bytes
    global_batch: int
    extra_bytes: int = 0               # embed/head params, replicated per rank
    # stacked families run every [n_stages, L] slot at the SAME block shape
    # (identity padding keeps its flops and zero params' bytes), so stage
    # cost/bytes go as L_per_stage x the largest slot; switch families
    # (U-Net) run each stage's own code, so stages cost their layer sums.
    stacked: bool = True

    def __post_init__(self):
        if len(self.layer_flops) != len(self.layer_bytes):
            raise ValueError("layer_flops and layer_bytes disagree on the "
                             "layer count")
        if not self.layer_flops:
            raise ValueError("profile needs at least one layer")

    @property
    def n_layers(self) -> int:
        return len(self.layer_flops)

    @property
    def total_flops(self) -> float:
        return float(sum(self.layer_flops))

    def stage_costs(self, n_stages: int,
                    partition: Tuple[int, ...]) -> Tuple[List[float], List[int]]:
        """Per-GLOBAL-stage (forward flops, param bytes) under a layout."""
        lay = partition_layout(self.n_layers, n_stages, partition or None)
        if self.stacked:
            slot_f = max(self.layer_flops)
            slot_b = max(self.layer_bytes)
            return ([lay.L_per_stage * slot_f] * n_stages,
                    [lay.L_per_stage * slot_b] * n_stages)
        flops, bytes_ = [], []
        for s in range(n_stages):
            lo, hi = lay.bounds[s], lay.bounds[s + 1]
            flops.append(float(sum(self.layer_flops[lo:hi])))
            bytes_.append(int(sum(self.layer_bytes[lo:hi])))
        # the switch executor pads every stage buffer to the largest stage
        pad = max(bytes_) if bytes_ else 0
        return flops, [pad] * n_stages


def profile_arch(arch: ArchConfig, shape: ShapeConfig) -> ModelProfile:
    """Profile a transformer-family ArchConfig for the planner."""
    flops, pbytes = balance.arch_layer_costs(arch, shape.seq_len)
    act_bytes = 2 if arch.param_dtype in ("bfloat16", "float16") else 4
    dtype_bytes = act_bytes
    extra = arch.vocab * arch.d_model * (1 if arch.tie_embeddings else 2) \
        * dtype_bytes
    return ModelProfile(
        name=arch.name,
        layer_flops=tuple(flops), layer_bytes=tuple(pbytes),
        carry_bytes_per_example=shape.seq_len * arch.d_model * act_bytes,
        global_batch=shape.global_batch, extra_bytes=extra, stacked=True)


def profile_unet(cfg, global_batch: int) -> ModelProfile:
    """Profile the sequentialized U-Net (heterogeneous switch stages)."""
    from repro.models.unet import build_layers
    layers = build_layers(cfg)
    carry = max(l.res * l.res * l.cin for l in layers) * 4   # fp32 NHWC
    return ModelProfile(
        name="unet",
        layer_flops=tuple(l.flops() for l in layers),
        layer_bytes=tuple(l.param_count() * 4 for l in layers),
        carry_bytes_per_example=carry,
        global_batch=global_batch, extra_bytes=0, stacked=False)


def microbatch_options(global_batch: int, pipe: int, dp: int = 1,
                       target_ratio: int = 8) -> List[int]:
    """Valid microbatch counts: divisors of the global batch whose per-micro
    batch still shards over the ``dp`` data axis, up to ``target_ratio *
    pipe`` micros (GPipe wants m >> n; beyond that the park grows for
    vanishing bubble gains)."""
    cap = min(global_batch, max(target_ratio * pipe, pipe))
    return [m for m in range(1, cap + 1)
            if global_batch % m == 0 and (global_batch // m) % dp == 0]


def _schedule_specs(pipe: int, n_layers: int,
                    executors: Sequence[str]) -> List[ScheduleSpec]:
    out = []
    for ex in executors:
        for base in ("gpipe_tasked", "1f1b", "zb"):
            out.append(ScheduleSpec(base=base, residuals="recompute",
                                    executor=ex))
        out.append(ScheduleSpec(base="zb", residuals="reuse", executor=ex))
        for v in (2,):
            if pipe * v <= n_layers and pipe > 1:
                out.append(ScheduleSpec(base="interleaved", virtual_stages=v,
                                        residuals="recompute", executor=ex))
    return out


def _partition_options(profile: ModelProfile,
                       n_stages: int) -> List[Tuple[int, ...]]:
    """Uniform layout plus the balance cuts, deduplicated."""
    uniform_sizes = partition_layout(profile.n_layers, n_stages).sizes
    opts: List[Tuple[int, ...]] = [()]
    seen = {uniform_sizes}
    for costs in (profile.layer_flops, profile.layer_bytes):
        cut = tuple(balance.block_partition(list(costs), n_stages))
        if cut not in seen:
            seen.add(cut)
            opts.append(cut)
    return opts


def score_candidate(profile: ModelProfile, hw: HardwareSpec, spec: PlanSpec,
                    *, remat: str = "dots") -> PlanCandidate:
    """Device-model time + exact per-rank memory for one PlanSpec."""
    sched = spec.schedule
    pipe, m, v = spec.pipe, spec.microbatches, sched.virtual_stages
    n_stages = pipe * v
    mb = profile.global_batch // m
    carry_bytes = mb * profile.carry_bytes_per_example
    stage_flops, stage_bytes = profile.stage_costs(n_stages, spec.partition)

    # stage-forward UNIT: 1/ranks of the model's per-micro forward compute
    unit_s = (profile.total_flops * mb / pipe) / hw.flops
    weights = [f * mb / hw.flops / unit_s for f in stage_flops]
    # bytes-priced comm terms: each payload class crosses the roofline
    # link at its own wire precision (the codec knob the search turns)
    wspec = wire_lib.WireSpec.parse(spec.wire)
    comm_units = wire_lib.hop_comm_units(
        carry_bytes, wspec.chain, hw.link_bw, unit_s, block=wspec.block)
    bwd_comm_units = wire_lib.hop_comm_units(
        carry_bytes, wspec.cotangent, hw.link_bw, unit_s, block=wspec.block)

    cost = plan_lib.plan_cost(
        sched.name, m, pipe, residuals=sched.residuals, remat=remat,
        executor=sched.executor, comm_cost=comm_units,
        bwd_comm_cost=bwd_comm_units,
        stage_weights=weights)
    wire_rep = wire_lib.plan_wire_report(
        plan_lib.plan_for(sched.name, m, pipe, residuals=sched.residuals,
                          wire=spec.wire),
        carry_bytes)

    # per-rank memory: hosted params (+grads/opt) + tick-loop carry slots
    # + residual stash.  Rank r hosts chunks {r, r + pipe, ...}.
    param_mult = 1.0 + hw.param_overhead
    mem = []
    for r in range(pipe):
        hosted = sum(stage_bytes[s] for s in range(r, n_stages, pipe))
        mb_slots = cost.carry_slots(r) + 2      # + in-flight compute in/out
        mem.append(int(
            (hosted + profile.extra_bytes) * param_mult
            + mb_slots * carry_bytes
            + cost.resid[r] * carry_bytes * hw.resid_bytes_factor))
    feasible = max(mem) <= hw.memory_bytes
    return PlanCandidate(
        spec=spec, step_units=cost.t_end, step_s=cost.t_end * unit_s,
        bubble=cost.bubble, comm_units=comm_units,
        mem_bytes=tuple(mem), mem_budget=float(hw.memory_bytes),
        feasible=feasible,
        wire_bytes_per_step=float(wire_rep["bytes_per_step"]),
        wire_ratio=float(wire_rep["ratio"]))


def plan_profile(profile: ModelProfile, hw: HardwareSpec, *,
                 base: Optional[ParallelConfig] = None,
                 shape_name: str = "",
                 microbatches: Optional[Sequence[int]] = None,
                 executors: Sequence[str] = ("spmd", "mpmd"),
                 wires: Optional[Sequence[str]] = None) -> PlanReport:
    """Search the full candidate space for one profiled model.

    ``executors`` restricts the executor leg of the search (e.g.
    ``("spmd",)`` on hosts where per-rank specialized compilation is not
    worth it).  ``wires`` enumerates the on-the-wire codec knob
    (WireSpec.parse strings); the default searches only the hardware
    spec's declared codec, so ``ParallelConfig.auto``-style callers keep
    the lossless (bitwise) default unless the hardware file or the caller
    opts into precision trades.
    """
    pipe = base.pipe if base is not None else hw.ranks
    remat = base.remat if base is not None else "dots"
    dp = base.data * base.pod * base.dp2 if base is not None else 1
    ms = list(microbatches) if microbatches is not None else \
        microbatch_options(profile.global_batch, pipe, dp)
    ws = list(wires) if wires is not None else [hw.wire]
    report = PlanReport(model=profile.name, shape=shape_name,
                        hardware=hw.to_dict())
    for sched in _schedule_specs(pipe, profile.n_layers, executors):
        n_stages = pipe * sched.virtual_stages
        for partition in _partition_options(profile, n_stages):
            for m in ms:
                for w in ws:
                    spec = PlanSpec(schedule=sched, pipe=pipe,
                                    microbatches=m, partition=partition,
                                    wire=w)
                    try:
                        report.candidates.append(
                            score_candidate(profile, hw, spec, remat=remat))
                    except ValueError:
                        # schedule constraint (e.g. interleaved needs m %
                        # pipe == 0): not a plan, not an error
                        continue
    return report


def plan_arch(arch, shape, hardware: Optional[HardwareSpec] = None, *,
              base: Optional[ParallelConfig] = None,
              microbatches: Optional[Sequence[int]] = None,
              executors: Sequence[str] = ("spmd", "mpmd"),
              wires: Optional[Sequence[str]] = None) -> PlanReport:
    """Plan a registered arch (by name or ArchConfig) on a hardware spec."""
    from repro import configs
    if isinstance(arch, str):
        arch = configs.get_arch(arch)
    if isinstance(shape, str):
        from repro.configs.base import SHAPES_BY_NAME
        shape = SHAPES_BY_NAME[shape]
    hw = hardware or HardwareSpec()
    profile = profile_arch(arch, shape)
    return plan_profile(profile, hw, base=base, shape_name=shape.name,
                        microbatches=microbatches, executors=executors,
                        wires=wires)
