"""Automatic parallelism planner (torchgpipe's missing autopilot).

torchgpipe hands the user a balance list and a chunks knob and wishes them
luck; PRs 1-5 of this repo built everything an autopilot needs — an
event-driven device-time simulator calibrated against measured schedules
(``core.schedules.simulate_device_times``), exact structural memory
predictors (the lowered plan's park / inbox / residual slot high-waters),
and a bitwise-verified schedule x residual x executor zoo.  This package
closes the loop:

* :mod:`repro.planner.hardware` — ``HardwareSpec``, the machine-readable
  ``hardware.yaml`` (ranks, per-rank memory, flops, interconnect bytes/s);
* :mod:`repro.planner.search` — profile the model, enumerate microbatch
  count x schedule x residuals x executor x balance partition, score each
  point with the device model (comm/overlap term included) under hard
  per-rank memory constraints;
* :mod:`repro.planner.report` — the ranked, JSON-round-trippable
  ``PlanReport`` whose top entry ``launch.dryrun --plan`` and
  ``steps.build_train_step`` consume directly.

Entry points: ``ParallelConfig.auto(arch, shape, hardware)`` for code,
``python -m repro.launch.hillclimb --arch A --shape S --hardware
hardware.yaml --top 5`` for the CLI.
"""
from repro.planner.hardware import HardwareSpec
from repro.planner.report import PlanCandidate, PlanReport
from repro.planner.search import (ModelProfile, microbatch_options,
                                  plan_arch, plan_profile, profile_arch,
                                  profile_unet, score_candidate)

__all__ = [
    "HardwareSpec", "ModelProfile", "PlanCandidate", "PlanReport",
    "microbatch_options", "plan_arch", "plan_profile", "profile_arch",
    "profile_unet", "score_candidate",
]
