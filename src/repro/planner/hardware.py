"""Machine-readable hardware description (`hardware.yaml`) for the planner.

The planner needs four numbers per accelerator pod-slice: how many pipe
ranks there are, how much memory each holds, how fast it computes, and how
fast the cross-rank interconnect moves a stage boundary.  ``HardwareSpec``
carries exactly that (defaults = one v5e slice of 4 chips), round-trips
through dict/JSON for the PlanReport, and loads from a small YAML file:

    # hardware.yaml
    name: v5e-4
    ranks: 4
    memory_bytes: 17179869184        # 16 GiB HBM per rank
    flops: 1.97e14                   # peak bf16 flops per rank
    ici_bytes_per_s: 5.0e10          # per-link interconnect bandwidth
    param_overhead: 3.0              # grads + adam moments, x param bytes
    resid_bytes_factor: 1.0          # residual slot bytes / carry bytes
    link_bandwidth_bytes_per_s: 5.0e10  # pipeline wire link bw (0 = ici)
    wire: fp32                       # default on-the-wire codec

``link_bandwidth_bytes_per_s`` prices the pipeline's inter-stage wire
traffic (chain carries, portal values, cotangents) — it defaults to the
ICI figure but can be set lower when stage boundaries cross a slower
fabric (e.g. DCN between pods).  ``wire`` is the default
``WireSpec.parse`` string the planner starts its wire-precision search
from.  PyYAML is optional: a flat ``key: value`` fallback parser handles
the schema above when the import is unavailable.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, replace
from typing import Any, Dict

from repro.configs.base import V5E


@dataclass(frozen=True)
class HardwareSpec:
    """One homogeneous slice of pipeline ranks, as the planner sees it."""
    name: str = "v5e"
    ranks: int = 4
    memory_bytes: float = float(V5E.hbm_bytes)
    flops: float = float(V5E.peak_flops_bf16)
    ici_bytes_per_s: float = float(V5E.ici_bw)
    # memory multiplier on hosted param bytes: gradients + optimizer state
    # (adam: m, v) on top of the parameters themselves.
    param_overhead: float = 3.0
    # residual-stash slot bytes as a fraction of one carry's bytes
    # (ZB-H1 reuse stores boundary-sized residuals per Bx slot).
    resid_bytes_factor: float = 1.0
    # pipeline wire link bandwidth for the bytes-priced comm term; the 0.0
    # sentinel falls back to ici_bytes_per_s (see ``link_bw``).
    link_bandwidth_bytes_per_s: float = 0.0
    # default on-the-wire codec (WireSpec.parse string) the wire-precision
    # search starts from.
    wire: str = "fp32"

    def __post_init__(self):
        if self.ranks < 1:
            raise ValueError(f"need ranks >= 1, got {self.ranks}")
        if self.memory_bytes <= 0 or self.flops <= 0 \
                or self.ici_bytes_per_s <= 0:
            raise ValueError("memory_bytes, flops, ici_bytes_per_s must be "
                             "positive")
        if self.link_bandwidth_bytes_per_s < 0:
            raise ValueError("link_bandwidth_bytes_per_s must be >= 0 "
                             "(0 = use ici_bytes_per_s)")
        from repro.core.wire import WireSpec
        WireSpec.parse(self.wire)         # rejects malformed wire strings

    @property
    def link_bw(self) -> float:
        """Effective pipeline wire bandwidth (bytes/s)."""
        return self.link_bandwidth_bytes_per_s or self.ici_bytes_per_s

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HardwareSpec":
        known = {f for f in cls.__dataclass_fields__}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown hardware.yaml keys: {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**{k: (int(v) if k == "ranks" else
                          str(v) if k in ("name", "wire") else float(v))
                      for k, v in d.items()})

    @classmethod
    def from_yaml(cls, path: str) -> "HardwareSpec":
        with open(path) as f:
            text = f.read()
        try:
            import yaml
            data = yaml.safe_load(text)
        except ImportError:
            data = _parse_flat_yaml(text)
        if not isinstance(data, dict):
            raise ValueError(f"{path}: expected a mapping of hardware keys, "
                             f"got {type(data).__name__}")
        return cls.from_dict(data)

    def with_(self, **kw) -> "HardwareSpec":
        return replace(self, **kw)


def _parse_flat_yaml(text: str) -> Dict[str, Any]:
    """Fallback for the flat `key: value` schema when PyYAML is absent."""
    out: Dict[str, Any] = {}
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if ":" not in line:
            raise ValueError(f"hardware.yaml: cannot parse line {raw!r}")
        k, v = (s.strip() for s in line.split(":", 1))
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v.strip("'\"")
    return out
