"""Ranked planner output: `PlanCandidate` rows inside a `PlanReport`.

The report is the planner's only artifact.  It serializes to JSON
(`to_json`/`from_json` round-trip through the structured `PlanSpec`
dicts), prints as a ranked table for the CLI, and its top feasible entry
feeds `dryrun --plan` / `steps.build_train_step` directly via
``report.best.spec.apply_to(pcfg)``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import PlanSpec


@dataclass(frozen=True)
class PlanCandidate:
    """One scored point of the search space."""
    spec: PlanSpec
    step_units: float            # device-model makespan, stage-forward units
    step_s: float                # the same, in seconds under the hardware
    bubble: float                # 1 - busy / (ranks * t_end)
    comm_units: float            # one chain hop, in stage-forward units
    mem_bytes: Tuple[int, ...]   # predicted peak bytes per rank
    mem_budget: float            # hardware.memory_bytes the plan was held to
    feasible: bool
    notes: str = ""
    wire_bytes_per_step: float = 0.0   # on-the-wire bytes, encoded
    wire_ratio: float = 1.0            # encoded / fp32 wire bytes

    @property
    def peak_mem_bytes(self) -> int:
        return max(self.mem_bytes) if self.mem_bytes else 0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "spec": self.spec.to_dict(),
            "step_units": self.step_units,
            "step_s": self.step_s,
            "bubble": self.bubble,
            "comm_units": self.comm_units,
            "mem_bytes": list(self.mem_bytes),
            "mem_budget": self.mem_budget,
            "feasible": self.feasible,
            "notes": self.notes,
            "wire_bytes_per_step": self.wire_bytes_per_step,
            "wire_ratio": self.wire_ratio,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlanCandidate":
        return cls(spec=PlanSpec.from_dict(d["spec"]),
                   step_units=float(d["step_units"]),
                   step_s=float(d["step_s"]),
                   bubble=float(d["bubble"]),
                   comm_units=float(d["comm_units"]),
                   mem_bytes=tuple(int(b) for b in d["mem_bytes"]),
                   mem_budget=float(d["mem_budget"]),
                   feasible=bool(d["feasible"]),
                   notes=str(d.get("notes", "")),
                   wire_bytes_per_step=float(
                       d.get("wire_bytes_per_step", 0.0)),
                   wire_ratio=float(d.get("wire_ratio", 1.0)))


@dataclass
class PlanReport:
    """Ranked candidates for one (model, shape, hardware) query.

    Candidates are ordered feasible-first, then by device-model step time;
    ``best`` is the top feasible entry (None when the budget admits no
    plan — shrink the model or raise ``memory_bytes``).
    """
    model: str
    shape: str
    hardware: Dict[str, Any]
    candidates: List[PlanCandidate] = field(default_factory=list)

    def ranked(self) -> List[PlanCandidate]:
        # rank by SECONDS: step_units are not comparable across microbatch
        # counts (one stage-forward unit scales with the per-micro batch)
        return sorted(self.candidates,
                      key=lambda c: (not c.feasible, c.step_s, c.step_units))

    @property
    def best(self) -> Optional[PlanCandidate]:
        for c in self.ranked():
            if c.feasible:
                return c
        return None

    def top(self, k: int) -> List[PlanCandidate]:
        return self.ranked()[:k]

    def to_dict(self) -> Dict[str, Any]:
        return {"model": self.model, "shape": self.shape,
                "hardware": self.hardware,
                "candidates": [c.to_dict() for c in self.ranked()]}

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlanReport":
        return cls(model=d["model"], shape=d["shape"],
                   hardware=dict(d["hardware"]),
                   candidates=[PlanCandidate.from_dict(c)
                               for c in d["candidates"]])

    @classmethod
    def from_json(cls, text: str) -> "PlanReport":
        return cls.from_dict(json.loads(text))

    def format_table(self, k: int = 10) -> str:
        """Human-readable ranked table for the CLI."""
        hdr = (f"PlanReport  model={self.model}  shape={self.shape}  "
               f"hardware={self.hardware.get('name', '?')} "
               f"(ranks={self.hardware.get('ranks', '?')}, "
               f"mem/rank={float(self.hardware.get('memory_bytes', 0)) / 2**30:.1f} GiB)")
        cols = (f"{'#':>2} {'schedule':<14} {'m':>3} {'resid':<9} "
                f"{'exec':<4} {'wire':<8} {'partition':<14} {'t[units]':>9} "
                f"{'t[ms]':>9} {'bubble':>6} {'wire[MiB]':>9} "
                f"{'mem[GiB]':>8} {'ok':>3}")
        lines = [hdr, cols, "-" * len(cols)]
        for i, c in enumerate(self.top(k)):
            s = c.spec
            part = ",".join(str(p) for p in s.partition) or "uniform"
            if len(part) > 14:
                part = part[:11] + "..."
            wire = s.wire if len(s.wire) <= 8 else "mixed"
            lines.append(
                f"{i + 1:>2} {s.schedule.name:<14} {s.microbatches:>3} "
                f"{s.schedule.residuals:<9} {s.schedule.executor:<4} "
                f"{wire:<8} {part:<14} {c.step_units:>9.2f} "
                f"{c.step_s * 1e3:>9.3f} {c.bubble:>6.3f} "
                f"{c.wire_bytes_per_step / 2**20:>9.1f} "
                f"{c.peak_mem_bytes / 2**30:>8.2f} "
                f"{'yes' if c.feasible else 'NO':>3}")
        if self.best is None:
            lines.append("(no feasible plan under the memory budget)")
        return "\n".join(lines)
