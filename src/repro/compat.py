"""JAX version compatibility shims.

The repo targets the current jax API (``jax.shard_map`` with ``axis_names``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.sharding
.get_abstract_mesh``) but must also run on jax 0.4.x (the CI pin and the
container toolchain).  Every call site imports the symbols from here instead
of probing jax itself, so the degradation story lives in exactly one module:

* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., axis_names=...,
  check_vma=False)`` — new-style keyword interface.  On 0.4.x it lowers to
  ``jax.experimental.shard_map.shard_map`` where the *manual* axis set is
  expressed inversely via ``auto = all_axes - axis_names`` and ``check_vma``
  is spelled ``check_rep``.
* ``set_mesh(mesh)`` — context manager.  On 0.4.x the legacy
  ``with mesh:`` thread-resources context provides the same "bare
  PartitionSpec resolves against the ambient mesh" behaviour.
* ``get_abstract_mesh()`` — returns the ambient (abstract) mesh or ``None``.
  On 0.4.x we return the legacy physical mesh from thread resources (or
  ``None`` when empty), which exposes the same ``.axis_names`` / ``.shape``
  surface the callers use.
* ``AxisType`` / ``make_mesh`` — explicit axis types landed after 0.4.x;
  the fallback enum is accepted (and ignored) by ``make_mesh``.
"""
from __future__ import annotations

import contextlib
import enum
import threading

import numpy as np

import jax

JAX_HAS_NEW_API = hasattr(jax, "shard_map")

_TLS = threading.local()


@contextlib.contextmanager
def manual_region():
    """Mark (at trace time) that we are inside a manual shard_map body.

    jax 0.4.x's partial-auto partitioner aborts (``Check failed:
    sharding.IsManualSubgroup()``) on ``with_sharding_constraint`` over the
    *auto* axes while inside a manual region; the constraints are layout
    hints, so on old jax we simply skip them there.
    """
    prev = getattr(_TLS, "manual", False)
    _TLS.manual = True
    try:
        yield
    finally:
        _TLS.manual = prev


def skip_constraints() -> bool:
    """True when sharding constraints must be elided (old jax, manual body)."""
    return not JAX_HAS_NEW_API and getattr(_TLS, "manual", False)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on new jax, a one-element
    list of dicts on 0.4.x."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


# --------------------------------------------------------------------- AxisType

try:
    from jax.sharding import AxisType  # jax >= 0.5
except ImportError:  # jax 0.4.x: explicit axis types don't exist; every
    class AxisType(enum.Enum):         # axis behaves as Auto under GSPMD.
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# -------------------------------------------------------------------- make_mesh

def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates the missing ``axis_types`` kwarg."""
    if JAX_HAS_NEW_API:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types,
                             devices=devices)
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def mesh_with_axis_types(devices: np.ndarray, axis_names, axis_types=None):
    """Construct ``jax.sharding.Mesh`` with axis_types when supported."""
    from jax.sharding import Mesh
    if JAX_HAS_NEW_API and axis_types is not None:
        return Mesh(devices, axis_names, axis_types=axis_types)
    return Mesh(devices, axis_names)


# -------------------------------------------------------------------- shard_map

if JAX_HAS_NEW_API:
    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        if axis_names is None:
            auto = frozenset()
        else:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map_04(f, mesh, in_specs, out_specs,
                             check_rep=check_vma, auto=auto)


# --------------------------------------------------------------------- set_mesh

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):
    set_mesh = jax.sharding.use_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh):
        # Legacy thread-resources mesh context: bare PartitionSpecs in
        # with_sharding_constraint / jit resolve against ``mesh``.
        with mesh:
            yield mesh


# ------------------------------------------------------ pallas compiler params

def pallas_compiler_params():
    """TPU pallas CompilerParams class (named TPUCompilerParams on 0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


# ------------------------------------------------------- optimization_barrier

if JAX_HAS_NEW_API:
    optimization_barrier = jax.lax.optimization_barrier
else:
    # 0.4.x ships the primitive without a differentiation rule; mirror the
    # later-jax behaviour (barrier the cotangents too) via custom_vjp.
    @jax.custom_vjp
    def optimization_barrier(xs):
        return jax.lax.optimization_barrier(xs)

    def _ob_fwd(xs):
        return optimization_barrier(xs), None

    def _ob_bwd(_, cts):
        return (jax.lax.optimization_barrier(cts),)

    optimization_barrier.defvjp(_ob_fwd, _ob_bwd)


# ------------------------------------------------------------ get_abstract_mesh

def get_abstract_mesh():
    """Ambient mesh (abstract on new jax, physical on 0.4.x) or ``None``."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh
