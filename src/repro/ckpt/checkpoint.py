"""Distributed checkpointing: sharded, atomic, async, restartable.

Layout (one directory per step):

    <dir>/step_000100/
        meta.json            — step, tree structure, shard layout, config hash
        shard_p0.npz         — this process's param/opt shards (addressable)
    <dir>/step_000100.COMMIT — written last; a checkpoint without COMMIT is
                               ignored at restore (atomic-commit protocol,
                               survives mid-write preemption)

Every process writes only its addressable shards; restore device_puts each
leaf with its target sharding (single-host here covers the whole tree, the
protocol is the multi-host one).  An async writer thread moves the
serialization off the training loop; `wait()` joins it (called before the
next save and at exit).  Retention keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax


def _flat_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path), leaf) for path, leaf in flat]


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_write: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()
        arrays = {}
        for name, leaf in _flat_with_paths(tree):
            arrays[name] = np.asarray(leaf)       # device->host sync copy
        meta = {"step": step, "extra": extra or {},
                "names": sorted(arrays), "time": time.time()}

        def write():
            try:
                path = self._step_dir(step)
                tmp = path + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "shard_p0.npz"), **arrays)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(path):   # re-save of same step: overwrite
                    shutil.rmtree(path)
                os.rename(tmp, path)
                with open(path + ".COMMIT", "w") as f:
                    f.write(str(step))
                self._gc()
            except BaseException as e:   # surfaced by wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for f in os.listdir(self.directory):
            if f.endswith(".COMMIT"):
                steps.append(int(f[len("step_"):-len(".COMMIT")]))
        return max(steps) if steps else None

    def restore(self, step: int, tree_like: Any,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like`` (abstract ok)."""
        self.wait()
        path = self._step_dir(step)
        if not os.path.exists(path + ".COMMIT"):
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        data = np.load(os.path.join(path, "shard_p0.npz"))
        meta = json.load(open(os.path.join(path, "meta.json")))
        names = [n for n, _ in _flat_with_paths(tree_like)]
        leaves = []
        shard_list = ([s for _, s in _flat_with_paths(shardings)]
                      if shardings is not None else [None] * len(names))
        for name, sh in zip(names, shard_list):
            arr = data[name]
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(tree_like)
        flat_idx = {n: i for i, (n, _) in enumerate(_flat_with_paths(tree_like))}
        ordered = [leaves[flat_idx[n]] for n, _ in _flat_with_paths(tree_like)]
        return jax.tree_util.tree_unflatten(treedef, ordered), meta

    def restore_latest(self, tree_like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore(step, tree_like, shardings)

    # ------------------------------------------------------------------- gc
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:06d}")

    def _gc(self):
        steps = sorted(
            int(f[len("step_"):-len(".COMMIT")])
            for f in os.listdir(self.directory) if f.endswith(".COMMIT"))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
            try:
                os.remove(self._step_dir(s) + ".COMMIT")
            except OSError:
                pass
