"""torchgpipe.balance analogue: automatic layer -> stage partitioning.

The paper's ``torchgpipe.balance`` profiles per-layer resource use and applies
the block-partition algorithm of Bárány & Grinberg [2] to find a contiguous
partition with small pairwise discrepancy.  In a construct-and-run framework
the profiling step maps naturally onto per-layer compiled HLO cost analysis
(``balance_by_flops``) or parameter byte counts (``balance_by_size``) — no
wall-clock run is required.

``block_partition`` solves the canonical contiguous-partition minimax problem
exactly (binary search on the bottleneck value + greedy feasibility check,
O(L log sum)).  This dominates the pairwise-discrepancy heuristic of [2] for
our purpose (minimizing the slowest stage = pipeline period).
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp

from repro import compat


def _feasible(costs: Sequence[float], n: int, cap: float) -> bool:
    blocks, acc = 1, 0.0
    for c in costs:
        if c > cap:
            return False
        if acc + c > cap:
            blocks += 1
            acc = c
            if blocks > n:
                return False
        else:
            acc += c
    return True


def block_partition(costs: Sequence[float], n: int) -> List[int]:
    """Partition ``costs`` into ``n`` contiguous blocks minimizing the max
    block sum.  Returns per-block sizes (len == n, sums to len(costs)).

    Every block is non-empty when ``len(costs) >= n``; otherwise trailing
    blocks are empty (the pipeline pads them with identity stages).
    """
    costs = [float(c) for c in costs]
    if n < 1:
        raise ValueError("need n >= 1")
    if len(costs) < n:
        return [1] * len(costs) + [0] * (n - len(costs))
    lo = max(costs) if costs else 0.0
    hi = sum(costs)
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if _feasible(costs, n, mid):
            hi = mid
        else:
            lo = mid
    cap = hi * (1 + 1e-12)
    # greedy split under cap, then rebalance so no block is empty
    sizes: List[int] = []
    acc, cnt = 0.0, 0
    for c in costs:
        if acc + c > cap and cnt > 0:
            sizes.append(cnt)
            acc, cnt = c, 1
        else:
            acc += c
            cnt += 1
    sizes.append(cnt)
    while len(sizes) < n:
        # split the largest block (by cost) that has >= 2 layers
        starts = [sum(sizes[:k]) for k in range(len(sizes))]
        best, best_cost = None, -1.0
        for k, sz in enumerate(sizes):
            if sz >= 2:
                c = sum(costs[starts[k]:starts[k] + sz])
                if c > best_cost:
                    best, best_cost = k, c
        if best is None:
            sizes.append(0)
            continue
        sz = sizes[best]
        sizes[best:best + 1] = [sz // 2 + sz % 2, sz // 2]
    assert len(sizes) == n and sum(sizes) == len(costs)
    return sizes


def partition_bounds(sizes: Sequence[int]) -> List[int]:
    """Cumulative stage boundaries: stage j owns layers [b[j], b[j+1])."""
    out = [0]
    for s in sizes:
        out.append(out[-1] + s)
    return out


def balance_by_size(param_bytes: Sequence[int], n: int) -> List[int]:
    """Partition layers by parameter byte counts (torchgpipe balance_by_size)."""
    return block_partition(param_bytes, n)


def balance_by_flops(layer_fns: Sequence[Callable], example_inputs, n: int) -> List[int]:
    """Partition layers by compiled per-layer HLO FLOPs.

    This is the construct-and-run analogue of torchgpipe's ``balance_by_time``
    profiling pass: instead of timing an eager forward, each layer is lowered
    and compiled standalone and its ``cost_analysis()['flops']`` is the cost.
    ``example_inputs[k]`` is the (abstract or concrete) input of layer ``k``.
    """
    costs = []
    for fn, x in zip(layer_fns, example_inputs):
        compiled = jax.jit(fn).lower(x).compile()
        costs.append(float(compat.cost_analysis(compiled).get("flops", 0.0))
                     or 1.0)
    return block_partition(costs, n)


def arch_layer_costs(arch, seq_len: int = 0):
    """Analytic per-layer (flops_per_example, param_bytes) for an ArchConfig.

    The planner's analogue of torchgpipe's profiling pass, computed from the
    architecture instead of a wall-clock run.  Layers are listed in pipeline
    order — for encoder-decoder archs the ``enc_layers`` encoder blocks come
    first, then the ``n_layers`` decoder blocks (which carry the extra
    cross-attention term).  Only *relative* weights matter for partitioning;
    the flops model is matmul-dominant: ``2 * params * tokens`` plus the
    attention score/value quadratic term.
    """
    d = arch.d_model
    dtype_bytes = 2 if arch.param_dtype in ("bfloat16", "float16") else 4
    attn = arch.attn
    heads_dim = attn.n_heads * attn.head_dim if attn is not None and \
        attn.kind != "none" else 0

    def attn_quad(tokens: int, kv_len: int) -> float:
        # QK^T + attn @ V: 2 * 2 * tokens * kv_len * n_heads * head_dim
        return 4.0 * tokens * kv_len * heads_dim

    base_params = arch.layer_params()
    cross_params = 4 * d * heads_dim if arch.is_encdec else 0
    seq = seq_len or 1
    enc_len = arch.enc_len or seq

    flops: List[float] = []
    bytes_: List[int] = []
    if arch.is_encdec:
        for _ in range(arch.enc_layers):
            flops.append(2.0 * base_params * enc_len + attn_quad(enc_len, enc_len))
            bytes_.append(base_params * dtype_bytes)
        for _ in range(arch.n_layers):
            flops.append(2.0 * (base_params + cross_params) * seq
                         + attn_quad(seq, seq) + attn_quad(seq, enc_len))
            bytes_.append((base_params + cross_params) * dtype_bytes)
    else:
        per = 2.0 * base_params * seq + (attn_quad(seq, seq) if heads_dim else 0.0)
        for _ in range(arch.n_layers):
            flops.append(per)
            bytes_.append(base_params * dtype_bytes)
    return flops, bytes_


def max_block_cost(costs: Sequence[float], sizes: Sequence[int]) -> float:
    b = partition_bounds(sizes)
    return max((sum(costs[b[j]:b[j + 1]]) for j in range(len(sizes))), default=0.0)
