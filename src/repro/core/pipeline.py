"""GPipe micro-batch pipeline parallelism as a JAX transform (paper §2–3).

The pipeline runs inside a :func:`jax.shard_map` that is *manual* over the
``pipe`` mesh axis and *auto* (GSPMD) over every other axis (``pod``,
``data``, ``tp``): stage ``j``'s parameters live on pipe-rank ``j`` (the
leading axis of the stacked stage parameters is sharded over ``pipe``), while
FSDP/TP/DP sharding inside a stage is delegated to the compiler via
``with_sharding_constraint`` — the paper's "device j holds partition j"
placement, generalized to a 512-chip mesh.

The deterministic clock-cycle (paper Algorithm 1) is a loop over ticks
``t = 0 .. m+n-2``; at tick ``t``, pipe-rank ``j`` executes task
``F_{t-j, j}`` (ranks whose ``t - j`` falls outside ``[0, m)`` are in the
fill/drain bubble and compute on zeros; their results are masked out of the
collected outputs, so autodiff assigns them exactly zero cotangent and the
bubble contributes nothing to gradients).  Boundary activations move with a
single-step ``collective-permute`` ring shift; skip tensors move via portals
(:mod:`repro.core.skip`).  ``jax.grad`` through the loop yields the reverse
clock-cycle with rematerialization scheduled immediately before each stage
backward — the paper's fork/join + Checkpoint/Recompute pairing, obtained
structurally (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.compat import get_abstract_mesh, shard_map
from repro.configs.base import ParallelConfig
from repro.core import checkpointing
from repro.core import plan as plan_lib
from repro.core.skip import SkipSpec, portal_sends, ring_init, ring_push, ring_read

PIPE_AXIS = "pipe"


@dataclass
class TickCtx:
    """Per-tick context handed to the stage function."""
    stage: jax.Array          # axis_index('pipe') — traced
    micro: jax.Array          # clamped micro-batch index  t - stage
    valid: jax.Array          # bool: is (micro, stage) a real task this tick?
    t: Any                    # tick counter (traced in scan mode, int if unrolled)
    fresh: Any                # stage-0 input pytree slice for this tick
    n_stages: int
    n_micro: int


# StageApplyFn signature:
#   stage_apply(stage_params, carry, skips_in: dict, resident, ctx: TickCtx)
#       -> (carry_out, skips_out: dict, resident_out)
StageApplyFn = Callable[..., Tuple[Any, Dict[str, Any], Any]]


def _select(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _shift_chain(value, n: int, axis: str):
    """Main pipeline hop: rank j -> j+1 (rank 0 receives zeros)."""
    if n == 1:
        return jax.tree.map(jnp.zeros_like, value)
    perm = [(i, i + 1) for i in range(n - 1)]
    return jax.tree.map(lambda v: jax.lax.ppermute(v, axis, perm), value)


def _shift_chain_rev(value, n: int, axis: str):
    """Backward (cotangent) hop: rank j -> j-1 (rank n-1 receives zeros)."""
    if n == 1:
        return jax.tree.map(jnp.zeros_like, value)
    perm = [(i, i - 1) for i in range(1, n)]
    return jax.tree.map(lambda v: jax.lax.ppermute(v, axis, perm), value)


BATCH_AXES = ("pod", "data")


def _constrain_batch0(tree, *, lead: int = 0):
    """Constrain pytree leaves: batch dim = ``lead`` over (pod, data).

    GSPMD does not reliably propagate the data sharding of the mini-batch
    into the clock-loop carries (state, outputs, per-tick slices) that start
    from jnp.zeros — without these constraints every carry is replicated
    over the data axis and per-device memory blows up by |data|x.
    """
    if compat.skip_constraints():
        return tree
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or not set(BATCH_AXES) <= set(mesh.axis_names):
        return tree

    nshard = 1
    for ax in BATCH_AXES:
        nshard *= mesh.shape[ax]

    def one(a):
        if a.ndim <= lead or a.shape[lead] % nshard:
            return a
        spec = [None] * a.ndim
        spec[lead] = BATCH_AXES
        return jax.lax.with_sharding_constraint(a, P(*spec))
    return jax.tree.map(one, tree)


def _barrier(*trees):
    """Ablation hook (overlap=False): serialize comm against compute, the
    analogue of torchgpipe's default-stream (no copy-stream) baseline."""
    flat, tds = zip(*[jax.tree_util.tree_flatten(t) for t in trees])
    leaves = [l for f in flat for l in f]
    if not leaves:
        return trees
    out = compat.optimization_barrier(tuple(leaves))
    res, k = [], 0
    for f, td in zip(flat, tds):
        res.append(jax.tree_util.tree_unflatten(td, out[k:k + len(f)]))
        k += len(f)
    return tuple(res)


# ---------------------------------------------------------------------------
# The clock-cycle loop (runs INSIDE shard_map, manual over 'pipe')
# ---------------------------------------------------------------------------

def run_pipeline(stage_apply: StageApplyFn,
                 stage_params,
                 inputs_mb,
                 cfg: ParallelConfig,
                 *,
                 skips: Sequence[SkipSpec] = (),
                 skip_protos: Optional[Dict[str, Any]] = None,
                 resident=None,
                 carry_proto=None,
                 axis: str = PIPE_AXIS,
                 rank=None):
    """Execute the GPipe schedule for one mini-batch.

    Args:
      stage_apply: per-stage function, see StageApplyFn.
      stage_params: this rank's stage parameters (already squeezed).
      inputs_mb: pytree with leading micro-batch axis [m, ...] (replicated
        over pipe; only rank 0 consumes it as ``ctx.fresh``).
      cfg: ParallelConfig (n_micro, pipe, remat, portals, overlap, ...).
      skips: skip edges (portal or threaded per cfg.portals).
      skip_protos: {name: pytree of ShapeDtypeStruct} for ring/slot init.
      resident: rank-local pytree (KV caches / SSM state), updated only on
        valid ticks.
      carry_proto: pytree of ShapeDtypeStruct describing the stage-boundary
        carry. Defaults to the structure of one fresh input slice.

    Returns: (outputs [m, ...carry], resident) — outputs valid on last rank.
    """
    n, m = cfg.pipe, cfg.n_micro
    T = m + n - 1
    # pipe == 1 runs outside shard_map (see pipeline_call): no axis to index.
    # ``rank`` (a P(pipe)-sharded iota slice) replaces jax.lax.axis_index:
    # the raw partition-id op it lowers to is rejected by 0.4.x's
    # partial-auto partitioner, while a sharded input works everywhere.
    if rank is not None:
        idx = rank
    else:
        idx = jax.lax.axis_index(axis) if n > 1 else jnp.zeros((), jnp.int32)
    skip_protos = skip_protos or {}
    resident = {} if resident is None else resident

    def zeros_of(proto):
        return jax.tree.map(
            lambda p: jnp.zeros(tuple(p.shape), jnp.dtype(p.dtype)), proto)

    if carry_proto is None:
        carry0 = jax.tree.map(lambda a: jnp.zeros(a.shape[1:], a.dtype), inputs_mb)
    else:
        carry0 = zeros_of(carry_proto)
    outputs0 = jax.tree.map(lambda c: jnp.zeros((m,) + c.shape, c.dtype), carry0)

    if cfg.portals:
        comms0 = {s.name: ring_init(s, skip_protos[s.name]) for s in skips}
    else:
        comms0 = {s.name: zeros_of(skip_protos[s.name]) for s in skips}

    inputs_mb = _constrain_batch0(inputs_mb, lead=1)
    streaming = cfg.stream_inputs and n > 1
    k = m // n if streaming else 0   # micro-batches per rank (validated in
    #                                  pipeline_call: m % n == 0)

    # The tick loop is generated from the validated clock-cycle task table
    # (schedules.clock_cycles, paper Algorithm 1) rather than inline
    # ``F_{t-j,j}`` arithmetic: micro/valid per (tick, rank) are plan
    # constants.  Forward-only execution is schedule-invariant — a
    # flush-synchronous 1F1B has the identical forward wavefront; the
    # schedules only diverge once backwards interleave (run_pipeline_tasks).
    fplan = plan_lib.lower_forward(m, n)
    fp_micro = jnp.asarray(fplan.micro)
    fp_valid = jnp.asarray(fplan.valid)

    def tick_body(state, comms, outputs, resident, t, micro_row, valid_row,
                  stream_buf=None):
        state = _constrain_batch0(state)
        outputs = _constrain_batch0(outputs, lead=1)
        if streaming:
            # stream_buf slot s holds micro-batch s*n + ((t + rank) mod n):
            # after t one-hop rotations, rank 0's slot t//n is micro-batch t.
            fresh = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, jnp.clip(t // n, 0, k - 1), 0, keepdims=False),
                stream_buf)
        else:
            # micro_row[0] == min(t, m-1): stage 0's plan entry; other ranks
            # ignore ``fresh`` (their stage_apply selects the carry).
            fresh = _constrain_batch0(jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, micro_row[0], 0, keepdims=False), inputs_mb))
        micro = micro_row[idx]
        valid = valid_row[idx]
        ctx = TickCtx(stage=idx, micro=micro, valid=valid, t=t, fresh=fresh,
                      n_stages=n, n_micro=m)

        # --- skip consumption --------------------------------------------
        skips_in = {}
        for s in skips:
            if cfg.portals:
                rd = None
                for dst in s.dsts:
                    v = ring_read(s, dst, comms[s.name][dst])
                    rd = v if rd is None else _select(idx == dst, v, rd)
                skips_in[s.name] = rd
            else:
                skips_in[s.name] = comms[s.name]

        # --- compute -------------------------------------------------------
        fn = checkpointing.wrap_stage(
            lambda p, c, si, r: stage_apply(p, c, si, r, ctx), cfg.remat)
        carry_out, skips_out, resident_new = fn(stage_params, state, skips_in,
                                                resident)
        # bubble ticks must not mutate resident state (KV caches etc.)
        resident = _select(valid, resident_new, resident)

        # --- sends -----------------------------------------------------------
        if not cfg.overlap:
            (carry_out,), = (_barrier(carry_out),)
        carry_out = _constrain_batch0(carry_out)
        state_next = _shift_chain(carry_out, n, axis)
        comms_next = {}
        for s in skips:
            v = skips_out[s.name]
            if cfg.portals:
                recvs = portal_sends(s, v, axis)
                comms_next[s.name] = {
                    dst: ring_push(comms[s.name][dst], recvs[dst])
                    for dst in s.dsts}
            else:
                # threaded: slot travels with the micro-batch, hop by hop
                slot = _select(idx == s.src_stage, v, skips_in[s.name])
                comms_next[s.name] = _shift_chain(slot, n, axis)

        # --- output collection at the last stage --------------------------
        slot_i = micro
        take = jnp.logical_and(idx == n - 1, valid)

        def upd(buf, y):
            cur = jax.lax.dynamic_index_in_dim(buf, slot_i, 0, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.where(take, y, cur), slot_i, 0)

        outputs = jax.tree.map(upd, outputs, carry_out)

        if streaming:
            # rotate the input stream one rank towards stage 0 (full ring).
            rot = [(i, (i - 1) % n) for i in range(n)]
            stream_buf = jax.tree.map(
                lambda a: jax.lax.ppermute(a, axis, rot), stream_buf)
            return state_next, comms_next, outputs, resident, stream_buf
        return state_next, comms_next, outputs, resident

    stream0 = inputs_mb if streaming else None

    if cfg.unroll_ticks:
        state, comms, outputs, stream = carry0, comms0, outputs0, stream0
        for t in range(T):
            out = tick_body(state, comms, outputs, resident,
                            jnp.asarray(t), fp_micro[t], fp_valid[t], stream)
            if streaming:
                state, comms, outputs, resident, stream = out
            else:
                state, comms, outputs, resident = out
    else:
        def scan_body(loop, xs):
            t, micro_row, valid_row = xs
            if streaming:
                state, comms, outputs, resident, stream = loop
                return tick_body(state, comms, outputs, resident, t,
                                 micro_row, valid_row, stream), None
            state, comms, outputs, resident = loop
            return tick_body(state, comms, outputs, resident, t,
                             micro_row, valid_row), None
        init = ((carry0, comms0, outputs0, resident, stream0) if streaming
                else (carry0, comms0, outputs0, resident))
        final, _ = jax.lax.scan(scan_body, init,
                                (jnp.arange(T), fp_micro, fp_valid))
        outputs, resident = final[2], final[3]

    return outputs, resident


# ---------------------------------------------------------------------------
# Fused schedule executor: forwards AND explicit-VJP backwards in one loop
# ---------------------------------------------------------------------------

def _oldjax_batch_axes(mesh, axis):
    """Old-jax fully-manual fallback: the non-pipe mesh axes become explicit
    batch parallelism.  Returns (axes, their size product)."""
    baxes = tuple(a for a in mesh.axis_names if a != axis)
    nd = 1
    for a in baxes:
        nd *= mesh.shape[a]
    return baxes, nd


def _oldjax_divisibility_error(nd):
    return ValueError("jax 0.4.x fallback pipeline needs the micro-batch "
                      f"divisible by pod*data*tp = {nd}")


def _dyn_read(buf_tree, slot):
    s = jnp.maximum(slot, 0)
    return jax.tree.map(
        lambda b: jax.lax.dynamic_index_in_dim(b, s, 0, keepdims=False),
        buf_tree)


def _masked_write(buf_tree, val_tree, slot, pred):
    s = jnp.maximum(slot, 0)

    def upd(b, v):
        cur = jax.lax.dynamic_index_in_dim(b, s, 0, keepdims=False)
        new = jnp.where(pred, v.astype(b.dtype), cur)
        return jax.lax.dynamic_update_index_in_dim(b, new, s, 0)
    return jax.tree.map(upd, buf_tree, val_tree)


def run_pipeline_tasks(stage_apply: StageApplyFn,
                       stage_params,
                       head_params,
                       inputs_mb,
                       loss_args_mb,
                       cfg: ParallelConfig,
                       *,
                       tplan: plan_lib.TaskPlan,
                       loss_fn,
                       carry_proto=None,
                       axis: str = PIPE_AXIS,
                       rank=None,
                       loss_scale: float = 1.0):
    """Execute a full F+B task table (GPipe or 1F1B) for one mini-batch.

    Unlike :func:`run_pipeline` (whose backward order is whatever autodiff
    induces — the GPipe reverse clock-cycle), this executor runs *backward
    tasks inside the primal loop*: a B tick pops the stashed boundary
    activation, recomputes the stage forward inside ``jax.vjp`` (the paper's
    Checkpoint/Recompute pairing, now structural), and ships the input
    cotangent down the reverse ring.  That is what lets 1F1B drain
    backwards early and bound the activation stash at ``min(n - j, m)``
    instead of ``m`` — the buffer is sized by the plan
    (``tplan.stash_depth``), so the memory win is structural.

    The last stage seeds each backward from ``loss_fn(head_params,
    carry_out, loss_args[micro])``; losses accumulate in ascending micro
    order on the last rank (identical in every schedule), and parameter
    cotangents are collected per-micro and reduced in a fixed order
    (``cfg.grad_reduce == "ordered"``), so any two schedules of the same
    computation produce bitwise-identical losses and gradients.
    ``grad_reduce == "running"`` instead folds cotangents in schedule order
    — O(1) extra memory, but bit-exact only against itself.

    Returns ``(loss_sum, stage_grads, head_grads, input_grads_mb)``:
    ``loss_sum`` is the un-normalized sum of per-micro losses on the last
    rank; grads already include the ``loss_scale / n_micro`` seed.
    """
    n, m = cfg.pipe, cfg.n_micro
    assert tplan.n_stages == n and tplan.n_micro == m
    T = tplan.n_ticks
    if rank is not None:
        idx = rank
    else:
        idx = jax.lax.axis_index(axis) if n > 1 else jnp.zeros((), jnp.int32)
    if cfg.grad_reduce not in ("ordered", "running"):
        raise ValueError(f"unknown grad_reduce {cfg.grad_reduce!r}; "
                         "want 'ordered' or 'running'")
    ordered = cfg.grad_reduce == "ordered"
    seed = jnp.asarray(loss_scale / m, jnp.float32)

    def zeros_of(proto):
        return jax.tree.map(
            lambda p: jnp.zeros(tuple(p.shape), jnp.dtype(p.dtype)), proto)

    if carry_proto is None:
        carry0 = jax.tree.map(lambda a: jnp.zeros(a.shape[1:], a.dtype),
                              inputs_mb)
    else:
        carry0 = zeros_of(carry_proto)

    def buf(depth, proto):
        return jax.tree.map(
            lambda c: jnp.zeros((depth,) + c.shape, c.dtype), proto)

    fresh0 = jax.tree.map(lambda a: jnp.zeros(a.shape[1:], a.dtype),
                          inputs_mb)
    stash0 = buf(tplan.stash_depth, carry0)
    f_inbox0 = buf(tplan.f_inbox_depth, carry0)
    b_inbox0 = buf(tplan.b_inbox_depth, carry0)
    igbuf0 = buf(m, fresh0)
    if ordered:
        g_stage0 = buf(m, stage_params)
        g_head0 = buf(m, head_params)
    else:
        g_stage0 = jax.tree.map(jnp.zeros_like, stage_params)
        g_head0 = jax.tree.map(jnp.zeros_like, head_params)

    zeros_carry = lambda: jax.tree.map(jnp.zeros_like, carry0)
    zeros_fresh = lambda: jax.tree.map(jnp.zeros_like, fresh0)
    zeros_p = lambda: jax.tree.map(jnp.zeros_like, stage_params)
    zeros_h = lambda: jax.tree.map(jnp.zeros_like, head_params)
    is_last = idx == n - 1

    def fwd_local(p_stage, carry_in, fresh, p_head, largs, micro, t):
        ctx = TickCtx(stage=idx, micro=micro, valid=jnp.asarray(True), t=t,
                      fresh=fresh, n_stages=n, n_micro=m)
        carry_out, _, _ = stage_apply(p_stage, carry_in, {}, {}, ctx)
        if not cfg.overlap:
            (carry_out,), = (_barrier(carry_out),)
        loss_i = jax.lax.cond(
            is_last,
            lambda: loss_fn(p_head, carry_out, largs).astype(jnp.float32),
            lambda: jnp.zeros((), jnp.float32))
        return carry_out, loss_i

    def nop_branch(x_f, stash_v, fresh, largs, bseed, micro, t):
        return (zeros_carry(), zeros_carry(), zeros_p(), zeros_h(),
                zeros_fresh(), jnp.zeros((), jnp.float32))

    def f_branch(x_f, stash_v, fresh, largs, bseed, micro, t):
        carry_out, loss_i = fwd_local(stage_params, x_f, fresh, head_params,
                                      largs, micro, t)
        return (carry_out, zeros_carry(), zeros_p(), zeros_h(),
                zeros_fresh(), loss_i)

    def b_branch(x_f, stash_v, fresh, largs, bseed, micro, t):
        def f(p, c, fr, ph):
            return fwd_local(p, c, fr, ph, largs, micro, t)
        # jax.vjp recomputes the stage forward from the stashed boundary
        # input and applies the cotangent immediately — remat-before-
        # backward with no residuals carried across ticks.
        _, vjp = jax.vjp(f, stage_params, stash_v, fresh, head_params)
        loss_bar = jnp.where(is_last, seed, 0.0).astype(jnp.float32)
        g_p, g_c, g_fr, g_ph = vjp((bseed, loss_bar))
        return (zeros_carry(), g_c, g_p, g_ph, g_fr,
                jnp.zeros((), jnp.float32))

    def tick_body(state, xs):
        (f_chain, b_chain, stash, f_inbox, b_inbox, loss_acc,
         g_stage, g_head, igbuf) = state
        t, kind_r, micro_r, ss_r, frs_r, frd_r, brs_r, brd_r = xs
        kind = kind_r[idx]
        micro = micro_r[idx]
        ss, frs, frd = ss_r[idx], frs_r[idx], frd_r[idx]
        brs, brd = brs_r[idx], brd_r[idx]

        # 1. park ring arrivals in the inboxes
        f_inbox = _masked_write(f_inbox, f_chain, frs, frs >= 0)
        b_inbox = _masked_write(b_inbox, b_chain, brs, brs >= 0)

        # 2. gather this tick's operands
        x_f = _select(frd >= 0, _dyn_read(f_inbox, frd), zeros_carry())
        stash_v = _dyn_read(stash, ss)
        bseed = _select(brd >= 0, _dyn_read(b_inbox, brd), zeros_carry())
        fresh = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, micro, 0,
                                                   keepdims=False), inputs_mb)
        largs = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, micro, 0,
                                                   keepdims=False),
            loss_args_mb)

        # 3. run exactly one task (XLA conditional: no masked double work)
        send_f, send_b, g_p, g_ph, g_fr, loss_i = jax.lax.switch(
            kind, (nop_branch, f_branch, b_branch),
            x_f, stash_v, fresh, largs, bseed, micro, t)

        # 4. commit state
        loss_acc = loss_acc + loss_i
        is_b = kind == plan_lib.BWD
        stash = _masked_write(stash, x_f, ss, (kind == plan_lib.FWD)
                              & (ss >= 0))
        if ordered:
            g_stage = _masked_write(g_stage, g_p, micro, is_b)
            g_head = _masked_write(g_head, g_ph, micro, is_b & is_last)
        else:
            g_stage = jax.tree.map(jnp.add, g_stage, g_p)
            g_head = jax.tree.map(jnp.add, g_head, g_ph)
        igbuf = _masked_write(igbuf, g_fr, micro, is_b & (idx == 0))
        f_chain = _shift_chain(send_f, n, axis)
        b_chain = _shift_chain_rev(send_b, n, axis)
        return (f_chain, b_chain, stash, f_inbox, b_inbox, loss_acc,
                g_stage, g_head, igbuf), None

    init = (zeros_carry(), zeros_carry(), stash0, f_inbox0, b_inbox0,
            jnp.zeros((), jnp.float32), g_stage0, g_head0, igbuf0)
    xs = (jnp.arange(T), jnp.asarray(tplan.kind), jnp.asarray(tplan.micro),
          jnp.asarray(tplan.stash_slot), jnp.asarray(tplan.f_recv_slot),
          jnp.asarray(tplan.f_read_slot), jnp.asarray(tplan.b_recv_slot),
          jnp.asarray(tplan.b_read_slot))
    if cfg.unroll_ticks:
        state = init
        for t in range(T):
            state, _ = tick_body(state, tuple(x[t] for x in xs))
    else:
        state, _ = jax.lax.scan(tick_body, init, xs)
    loss_acc, g_stage, g_head, igbuf = state[5], state[6], state[7], state[8]
    if ordered:
        # fixed-order reduction over the micro axis: the sum is identical
        # for every schedule, making gradients schedule-bitwise-stable.
        g_stage = jax.tree.map(lambda a: jnp.sum(a, axis=0), g_stage)
        g_head = jax.tree.map(lambda a: jnp.sum(a, axis=0), g_head)
    return loss_acc, g_stage, g_head, igbuf


def pipeline_grad_call(stage_apply: StageApplyFn,
                       *,
                       mesh: Mesh,
                       cfg: ParallelConfig,
                       loss_fn,
                       carry_proto=None,
                       axis: str = PIPE_AXIS):
    """Build the fused schedule-driven training call.

    Returns ``call(stage_params, head_params, inputs_mb, loss_args_mb) ->
    (loss, stage_grads, head_grads, input_grads_mb)`` where:

    * ``loss`` is the mean per-micro loss (matches ``head_loss`` over the
      full batch up to micro-chunked summation order),
    * ``stage_grads`` mirrors ``stage_params`` ([n_stages, ...], sharded
      over ``pipe``),
    * ``head_grads`` mirrors ``head_params`` (valid on the last rank),
    * ``input_grads_mb`` mirrors ``inputs_mb`` ([m, ...], valid on rank 0)
      — feed it to the embed VJP outside the pipeline.

    The schedule comes from ``cfg.schedule``: ``"1f1b"`` or
    ``"gpipe"``/``"gpipe_tasked"`` — both lowered by
    :func:`repro.core.plan.plan_for` from the validated task tables in
    :mod:`repro.core.schedules`.  Skip edges and resident state are not
    supported in the fused executor (use the autodiff path).
    """
    n, m = cfg.pipe, cfg.n_micro
    tplan = plan_lib.plan_for(cfg.schedule, m, n)

    def inner(rank_arr, params, head_params, inputs_mb, loss_args_mb,
              bdiv=1, psum_axes=()):
        with compat.manual_region():
            params = jax.tree.map(lambda a: a[0], params)

            def localize(proto):
                if proto is None or bdiv == 1:
                    return proto
                return jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(
                        (p.shape[0] // bdiv,) + tuple(p.shape[1:]), p.dtype),
                    proto)

            loss_sum, g_stage, g_head, ig = run_pipeline_tasks(
                stage_apply, params, head_params, inputs_mb, loss_args_mb,
                cfg, tplan=tplan, loss_fn=loss_fn,
                carry_proto=localize(carry_proto), axis=axis,
                rank=rank_arr[0], loss_scale=1.0 / bdiv)
            if psum_axes:
                # batch axes are manual here (old-jax fallback): the DP
                # gradient reduction is explicit.
                loss_sum, g_stage, g_head = jax.lax.psum(
                    (loss_sum, g_stage, g_head), psum_axes)
            loss = loss_sum * (1.0 / (bdiv * m))
            loss = loss[None]
            g_stage = jax.tree.map(lambda a: a[None], g_stage)
            g_head = jax.tree.map(lambda a: a[None], g_head)
            ig = jax.tree.map(lambda a: a[None], ig)
            return loss, g_stage, g_head, ig

    def call(stage_params, head_params, inputs_mb, loss_args_mb):
        rank_arr = jnp.arange(n, dtype=jnp.int32)
        if cfg.pipe > 1:
            axis_names = {axis}
            in_spec_x = in_spec_l = P()
            out_spec_ig = P(axis)
            bdiv, psum_axes = 1, ()
            if not compat.JAX_HAS_NEW_API:
                # Same old-jax fallback as pipeline_call: fully manual,
                # non-pipe axes become explicit batch parallelism.
                axis_names = set(mesh.axis_names)
                baxes, nd = _oldjax_batch_axes(mesh, axis)
                if nd > 1:
                    leaves = (jax.tree.leaves(inputs_mb)
                              + jax.tree.leaves(loss_args_mb))
                    if not all(l.ndim > 1 and l.shape[1] % nd == 0
                               for l in leaves):
                        raise _oldjax_divisibility_error(nd)
                    bdiv, psum_axes = nd, baxes
                    in_spec_x = in_spec_l = P(None, baxes)
                    out_spec_ig = P(axis, None, baxes)
            fn = shard_map(
                functools.partial(inner, bdiv=bdiv, psum_axes=psum_axes),
                mesh=mesh,
                in_specs=(P(axis), P(axis), P(), in_spec_x, in_spec_l),
                out_specs=(P(axis), P(axis), P(axis), out_spec_ig),
                axis_names=axis_names, check_vma=False)
        else:
            fn = inner
        loss, g_stage, g_head, ig = fn(rank_arr, stage_params, head_params,
                                       inputs_mb, loss_args_mb)
        loss = loss[-1]
        g_head = jax.tree.map(lambda a: a[-1], g_head)
        ig = jax.tree.map(lambda a: a[0], ig)
        return loss, g_stage, g_head, ig

    return call, tplan


# ---------------------------------------------------------------------------
# shard_map wrapper: the public entry point
# ---------------------------------------------------------------------------

def pipeline_call(stage_apply: StageApplyFn,
                  *,
                  mesh: Mesh,
                  cfg: ParallelConfig,
                  skips: Sequence[SkipSpec] = (),
                  skip_protos: Optional[Dict[str, Any]] = None,
                  carry_proto=None,
                  axis: str = PIPE_AXIS):
    """Build ``(stage_params, inputs_mb, resident) -> (outputs, resident)``.

    ``stage_params``/``resident`` leaves carry a leading ``n_stages`` axis
    sharded over ``pipe``; ``inputs_mb`` is replicated over ``pipe`` (its
    batch-ish dims may be sharded over the auto axes).  ``outputs`` gains a
    leading ``pipe``-sharded axis: index ``[-1]`` for the last stage's
    results (:func:`last_stage_output`).
    """
    # Input modes across the shard_map boundary:
    #  * replicated (default): the transpose of the pipe-replicated in_spec
    #    is a psum over the *manual* axis — this both dominates collective
    #    bytes for embedding-fed models AND crashes XLA-CPU's
    #    AllReducePromotion in bf16, so the inputs cross in fp32.
    #  * streaming (cfg.stream_inputs, m % n == 0): micro-batches are
    #    SHARDED over pipe (micro-batch i at rank i%n, slot i//n) and
    #    rotated one hop per tick; the transpose is a reverse rotation (no
    #    psum), memory drops by n, and bf16 is safe.
    def inner(rank_arr, params, inputs_mb, resident, in_dtypes, cfg_run,
              bdiv=1):
        def localize(proto):
            # protos describe GLOBAL batch shapes; inside a fully-manual
            # region (old-jax fallback) each rank holds 1/bdiv of the batch.
            if proto is None or bdiv == 1:
                return proto
            return jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(
                    (p.shape[0] // bdiv,) + tuple(p.shape[1:]), p.dtype),
                proto)

        with compat.manual_region():
            params = jax.tree.map(lambda a: a[0], params)
            resident = jax.tree.map(lambda a: a[0], resident)
            if cfg_run.stream_inputs:
                inputs_mb = jax.tree.map(lambda a: a[0], inputs_mb)
            inputs_mb = jax.tree.map(lambda a, d: a.astype(d), inputs_mb,
                                     in_dtypes)
            sk_protos = {k: localize(v)
                         for k, v in (skip_protos or {}).items()}
            outs, res = run_pipeline(stage_apply, params, inputs_mb, cfg_run,
                                     skips=skips, skip_protos=sk_protos,
                                     resident=resident,
                                     carry_proto=localize(carry_proto),
                                     axis=axis, rank=rank_arr[0])
            outs = jax.tree.map(lambda a: a[None], outs)
            res = jax.tree.map(lambda a: a[None], res)
            return outs, res

    def call(stage_params, inputs_mb, resident=None):
        resident = {} if resident is None else resident
        n, m = cfg.pipe, cfg.n_micro
        streaming = cfg.stream_inputs and n > 1 and m % n == 0
        cfg_run = cfg.with_(stream_inputs=streaming)
        in_dtypes = jax.tree.map(lambda a: a.dtype, inputs_mb)
        if streaming:
            k = m // n
            inputs_mb = jax.tree.map(
                lambda a: a.reshape((k, n) + a.shape[1:]).swapaxes(0, 1),
                inputs_mb)
            in_spec_x = P(axis)
            up = inputs_mb
        else:
            in_spec_x = P()
            up = jax.tree.map(
                lambda a: a.astype(jnp.float32)
                if a.dtype == jnp.bfloat16 else a, inputs_mb)
        rank_arr = jnp.arange(n, dtype=jnp.int32)
        if cfg.pipe > 1:
            axis_names = {axis}
            in_spec_res = out_spec_res = P(axis)
            out_spec_outs = P(axis)
            bdiv = 1
            if not compat.JAX_HAS_NEW_API:
                # jax 0.4.x: the partial-auto partitioner aborts on this
                # program shape (XLA IsManualSubgroup check), so go FULLY
                # manual and express what GSPMD would have derived by hand:
                # every non-pipe axis becomes batch parallelism.  The
                # tensor-parallel constraints inside the stage are already
                # elided (compat.skip_constraints), so treating ``tp`` as
                # extra DP is exact — each rank computes a distinct batch
                # slice and the shard_map transpose psums parameter
                # cotangents over the non-pipe axes (the DP grad reduction).
                axis_names = set(mesh.axis_names)
                baxes, nd = _oldjax_batch_axes(mesh, axis)
                bdim_in = 2 if streaming else 1
                if nd > 1:
                    def divisible(leaf, d):
                        return leaf.ndim > d and leaf.shape[d] % nd == 0
                    if not (all(divisible(l, bdim_in)
                                for l in jax.tree.leaves(up))
                            and all(l.ndim < 4 or divisible(l, 3)
                                    for l in jax.tree.leaves(resident))):
                        raise _oldjax_divisibility_error(nd)
                    bdiv = nd
                    if streaming:
                        in_spec_x = P(axis, None, baxes)
                    else:
                        in_spec_x = P(None, baxes)
                    # resident caches: [n, L, m, mb, ...] -> batch at dim 3;
                    # low-rank leaves (per-micro trackers) are replicated.
                    def res_spec(leaf):
                        if leaf.ndim >= 4:
                            return P(axis, None, None, baxes)
                        return P(axis)
                    in_spec_res = jax.tree.map(res_spec, resident)
                    out_spec_res = in_spec_res
                    out_spec_outs = P(axis, None, baxes)
            fn = shard_map(
                functools.partial(inner, in_dtypes=in_dtypes,
                                  cfg_run=cfg_run, bdiv=bdiv), mesh=mesh,
                in_specs=(P(axis), P(axis), in_spec_x, in_spec_res),
                out_specs=(out_spec_outs, out_spec_res),
                axis_names=axis_names, check_vma=False)
        else:
            # Degenerate single-stage pipeline: plain sequential execution,
            # no manual axis (avoids size-1 manual subgroups).
            fn = functools.partial(inner, in_dtypes=in_dtypes,
                                   cfg_run=cfg_run.with_(stream_inputs=False))
        return fn(rank_arr, stage_params, up, resident)

    return call


def last_stage_output(outputs):
    """Extract the last pipe rank's collected outputs: [m, ...] pytree."""
    return jax.tree.map(lambda a: a[-1], outputs)


def microbatch(tree, n_micro: int):
    """Split leading batch dim B -> [n_micro, B // n_micro, ...]."""
    def f(a):
        b = a.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} not divisible by n_micro {n_micro}")
        return a.reshape((n_micro, b // n_micro) + a.shape[1:])
    return jax.tree.map(f, tree)


def unmicrobatch(tree):
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), tree)
