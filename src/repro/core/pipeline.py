"""GPipe micro-batch pipeline parallelism as a JAX transform (paper §2–3).

The pipeline runs inside a :func:`jax.shard_map` that is *manual* over the
``pipe`` mesh axis and *auto* (GSPMD) over every other axis (``pod``,
``data``, ``tp``): stage ``j``'s parameters live on pipe-rank ``j`` (the
leading axis of the stacked stage parameters is sharded over ``pipe``), while
FSDP/TP/DP sharding inside a stage is delegated to the compiler via
``with_sharding_constraint`` — the paper's "device j holds partition j"
placement, generalized to a 512-chip mesh.

There is ONE execution engine: :func:`run_pipeline_tasks`, a scan over the
static event plan lowered by :mod:`repro.core.plan` from a validated
schedule task table (:mod:`repro.core.schedules`).  The plan is cut into
*segments* — maximal runs of ticks sharing a branch set — and each segment
runs its own scan with the ``lax.switch`` pruned to exactly the branches
that segment uses and the bookkeeping (grad writes, chain permutes, stream
rotation) elided when the segment provably never needs it.
``ParallelConfig.executor`` selects the segment lowering: the ``"spmd"``
reference traces the union branch set with dynamic rank indexing and
eager end-of-tick chain sends, while ``"mpmd"`` dispatches one
*specialized* tick body per rank (static columns, per-rank pruned
branches — ``plan.specialize``'s projection) under a top-level
rank-indexed switch and double-buffers the chain ``ppermute`` one tick
ahead so the hop overlaps the next stage compute; the two are
bitwise-identical.  Each tick, rank
``r`` runs at most one task — NOP (bubble), F, fused B, or the
split-backward pair Bx / Bw — boundary activations move with a
``collective-permute`` ring shift directly into plan-allocated *park* slots
(arrival buffer == activation stash, by donation), skip tensors move on
plan-lowered portal/threaded routes (paper §3.3), resident state (KV
caches) is read and updated on F ticks, and streamed inputs rotate towards
stage 0 on plan-flagged ticks.

Plan families select the backward story:

* **forward-only plans** (``gpipe_fwd``, paper Algorithm 1): the executor
  runs just the forward wavefront and ``jax.grad`` through it yields the
  reverse clock-cycle with rematerialization scheduled immediately before
  each stage backward — the paper's fork/join + Checkpoint/Recompute
  pairing, obtained structurally (DESIGN.md §2).  :func:`run_pipeline` /
  :func:`pipeline_call` are thin wrappers that lower this plan.

* **F+B plans** (``gpipe_tasked`` / ``1f1b`` / ``interleaved:v`` / ``zb``):
  backward tasks execute *inside* the same loop — a backward tick re-reads
  the parked boundary activation (and parked skip operands), recomputes
  the stage forward inside ``jax.vjp``, and ships input / skip cotangents
  down the reverse routes.  That is what lets 1F1B drain backwards early
  and bound the activation stash at ``min(n - j, m)`` instead of ``m``;
  see :func:`pipeline_grad_call`.  With interleaved virtual stages
  (``tplan.n_chunks > 1``) rank ``r`` holds a ``[v, ...]`` parameter block
  and each tick dynamically selects the chunk its task touches; the ring
  shift becomes a full rotation so chunk boundaries (rank n-1 -> rank 0)
  ride the same collective.  Split-backward plans run Bx (input cotangent
  only — the half other stages wait for) on the critical path and fill
  bubble ticks with Bw (weight gradient), re-reading the parked operands
  and the parked output cotangent.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.compat import get_abstract_mesh, shard_map
from repro.configs.base import ParallelConfig
from repro.core import checkpointing
from repro.core import plan as plan_lib
from repro.core.plan import BWD, BWD_W, BWD_X, FWD, NOP, pipe_ring_perm
from repro.core.skip import SkipSpec
from repro.runtime.compression import _dequantize_block, _quantize_block

PIPE_AXIS = "pipe"


@dataclass
class TickCtx:
    """Per-tick context handed to the stage function."""
    stage: jax.Array          # GLOBAL stage index (chunk * n_ranks + rank)
    micro: jax.Array          # micro-batch index of this rank's task
    valid: jax.Array          # bool: is this a real (scheduled) task?
    t: Any                    # tick counter (traced in scan mode, int if unrolled)
    fresh: Any                # stage-0 input pytree slice for this tick
    n_stages: int             # GLOBAL stage count (n_ranks * n_chunks)
    n_micro: int


# StageApplyFn signature:
#   stage_apply(stage_params, carry, skips_in: dict, resident, ctx: TickCtx)
#       -> (carry_out, skips_out: dict, resident_out)
StageApplyFn = Callable[..., Tuple[Any, Dict[str, Any], Any]]


def _select(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _shift_chain(value, n: int, axis: str, *, ring: bool = False):
    """Main pipeline hop: rank j -> j+1.  ``ring`` adds the wraparound pair
    (n-1 -> 0) that interleaved chunk boundaries ride; without it rank 0
    receives zeros."""
    if n == 1:
        # single rank: the wraparound hop (chunk c -> c+1) is an identity
        return value if ring else jax.tree.map(jnp.zeros_like, value)
    perm = pipe_ring_perm(n, ring=ring)
    return jax.tree.map(lambda v: jax.lax.ppermute(v, axis, perm), value)


def _shift_chain_rev(value, n: int, axis: str, *, ring: bool = False):
    """Backward (cotangent) hop: rank j -> j-1 (+ wraparound 0 -> n-1)."""
    if n == 1:
        return value if ring else jax.tree.map(jnp.zeros_like, value)
    perm = pipe_ring_perm(n, reverse=True, ring=ring)
    return jax.tree.map(lambda v: jax.lax.ppermute(v, axis, perm), value)


def _route_hop(value, perm, axis: str):
    """One skip-route hop: a static (src, dst) pair list ppermute.  An empty
    perm means src and dst share a rank — the hop is an identity hold."""
    if not perm:
        return value
    return jax.tree.map(
        lambda v: jax.lax.ppermute(v, axis, list(perm)), value)


BATCH_AXES = ("pod", "data")


def _constrain_batch0(tree, *, lead: int = 0):
    """Constrain pytree leaves: batch dim = ``lead`` over (pod, data).

    GSPMD does not reliably propagate the data sharding of the mini-batch
    into the clock-loop carries (state, outputs, per-tick slices) that start
    from jnp.zeros — without these constraints every carry is replicated
    over the data axis and per-device memory blows up by |data|x.
    """
    if compat.skip_constraints():
        return tree
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or not set(BATCH_AXES) <= set(mesh.axis_names):
        return tree

    nshard = 1
    for ax in BATCH_AXES:
        nshard *= mesh.shape[ax]

    def one(a):
        if a.ndim <= lead or a.shape[lead] % nshard:
            return a
        spec = [None] * a.ndim
        spec[lead] = BATCH_AXES
        return jax.lax.with_sharding_constraint(a, P(*spec))
    return jax.tree.map(one, tree)


def _barrier(*trees):
    """Ablation hook (overlap=False): serialize comm against compute, the
    analogue of torchgpipe's default-stream (no copy-stream) baseline."""
    flat, tds = zip(*[jax.tree_util.tree_flatten(t) for t in trees])
    leaves = [l for f in flat for l in f]
    if not leaves:
        return trees
    out = compat.optimization_barrier(tuple(leaves))
    res, k = [], 0
    for f, td in zip(flat, tds):
        res.append(jax.tree_util.tree_unflatten(td, out[k:k + len(f)]))
        k += len(f)
    return tuple(res)


def _oldjax_batch_axes(mesh, axis):
    """Old-jax fully-manual fallback: the non-pipe mesh axes become explicit
    batch parallelism.  Returns (axes, their size product)."""
    baxes = tuple(a for a in mesh.axis_names if a != axis)
    nd = 1
    for a in baxes:
        nd *= mesh.shape[a]
    return baxes, nd


def _oldjax_divisibility_error(nd):
    return ValueError("jax 0.4.x fallback pipeline needs the micro-batch "
                      f"divisible by pod*data*tp = {nd}")


def _dyn_read(buf_tree, slot):
    s = jnp.maximum(slot, 0)
    return jax.tree.map(
        lambda b: jax.lax.dynamic_index_in_dim(b, s, 0, keepdims=False),
        buf_tree)


def _masked_write(buf_tree, val_tree, slot, pred):
    s = jnp.maximum(slot, 0)

    def upd(b, v):
        cur = jax.lax.dynamic_index_in_dim(b, s, 0, keepdims=False)
        new = jnp.where(pred, v.astype(b.dtype), cur)
        return jax.lax.dynamic_update_index_in_dim(b, new, s, 0)
    return jax.tree.map(upd, buf_tree, val_tree)


def _masked_accum(buf_tree, val_tree, slot, pred):
    """Add ``val`` into row ``slot`` under ``pred`` (chunked grad rows:
    each chunk's backward deposits into its own disjoint sub-row)."""
    s = jnp.maximum(slot, 0)

    def upd(b, v):
        cur = jax.lax.dynamic_index_in_dim(b, s, 0, keepdims=False)
        new = jnp.where(pred, cur + v.astype(b.dtype), cur)
        return jax.lax.dynamic_update_index_in_dim(b, new, s, 0)
    return jax.tree.map(upd, buf_tree, val_tree)


def _zeros_of(proto):
    return jax.tree.map(
        lambda p: jnp.zeros(tuple(p.shape), jnp.dtype(p.dtype)), proto)


def _buf(depth, proto):
    return jax.tree.map(
        lambda c: jnp.zeros((depth,) + c.shape, c.dtype), proto)


def _vjp_split(fn, args, also_live=()):
    """vjp over all of ``args``, with the pullback flattened into leaves.

    ``jax.vjp``'s pullback is a :class:`jax.tree_util.Partial` pytree whose
    leaves are its residuals.  Leaves that are (by tracer identity) the
    live inputs themselves — the primal args, or ``also_live`` values the
    caller can rederive on a later tick (parked activations, labels of the
    same micro, resident state) — need not cross ticks; everything else is
    what the Bx tick must stash for residual reuse.  Returns
    ``(out, vjp_fn, leaves, treedef, stash_mask)`` where ``stash_mask[i]``
    is True for leaves that must be stashed.
    """
    out, vjp_fn = jax.vjp(fn, *args)
    leaves, treedef = jax.tree_util.tree_flatten(vjp_fn)
    live = set(map(id, jax.tree_util.tree_leaves((args, also_live))))
    mask = tuple(id(leaf) not in live for leaf in leaves)
    return out, vjp_fn, leaves, treedef, mask


# ---------------------------------------------------------------------------
# On-the-wire codec (plan.TaskPlan.wire): encode at latch, decode at arrival
# ---------------------------------------------------------------------------

def _float_leaf(p) -> bool:
    return jnp.issubdtype(jnp.dtype(p.dtype), jnp.floating)


class _Codec:
    """One payload class's wire codec, applied leaf-wise over carry trees.

    ``zeros(proto)`` builds the wire-format register/in-flight value the
    scan state holds; ``enc(value, ef, pred)`` encodes at the latch (or the
    SPMD eager send) and — for the stateful ``int8-ef`` codec — folds the
    quantization residual into the error-feedback state only when ``pred``
    says the send is real, keeping the EF sequence identical across
    executors; ``dec(wire, proto)`` reverses it at the arrival tick.
    Non-float leaves (token ids riding a forward-only carry) always pass
    through untouched, so every codec is exact on them.  ``fp32`` is a
    strict identity — wire trees equal value trees bitwise, which is what
    keeps the default mode bit-for-bit against the pre-codec executor.
    """

    def __init__(self, codec: str, block: int):
        self.codec = codec
        self.block = block
        self.stateful = codec == "int8-ef"

    def _q_shapes(self, p):
        n = 1
        for d in p.shape:
            n *= int(d)
        nb = max(-(-n // self.block), 1)
        return n, nb

    def zeros(self, proto):
        if self.codec == "fp32":
            return _zeros_of(proto)
        leaves, td = jax.tree_util.tree_flatten(proto)

        def one(p):
            if not _float_leaf(p):
                return jnp.zeros(tuple(p.shape), jnp.dtype(p.dtype))
            if self.codec == "bf16":
                return jnp.zeros(tuple(p.shape), jnp.bfloat16)
            n, nb = self._q_shapes(p)
            return {"q": jnp.zeros((nb, self.block), jnp.int8),
                    "s": jnp.zeros((nb, 1), jnp.float32)}
        return jax.tree_util.tree_unflatten(td, [one(p) for p in leaves])

    def ef_zeros(self, proto):
        """Error-feedback residual per float leaf (empty where exact)."""
        leaves, td = jax.tree_util.tree_flatten(proto)
        return jax.tree_util.tree_unflatten(
            td, [jnp.zeros(tuple(p.shape), jnp.float32)
                 if self.stateful and _float_leaf(p) else ()
                 for p in leaves])

    def enc(self, value, ef=(), pred=None):
        """value tree -> (wire tree, new ef tree)."""
        if self.codec == "fp32":
            return value, ef
        if self.codec == "bf16":
            return jax.tree.map(
                lambda v: v.astype(jnp.bfloat16) if _float_leaf(v) else v,
                value), ef
        leaves, td = jax.tree_util.tree_flatten(value)
        efs = td.flatten_up_to(ef) if self.stateful else [()] * len(leaves)

        def one(v, e):
            if not _float_leaf(v):
                return v, e
            y = v.astype(jnp.float32) + e
            flat = y.reshape(-1)
            q, s = _quantize_block(flat, self.block)
            deq = _dequantize_block(q, s, flat.shape[0]).reshape(v.shape)
            resid = y - deq
            new_e = jnp.where(pred, resid, e) if pred is not None else resid
            return {"q": q, "s": s}, new_e
        pairs = [one(v, e) for v, e in zip(leaves, efs)]
        wire = jax.tree_util.tree_unflatten(td, [w for w, _ in pairs])
        new_ef = jax.tree_util.tree_unflatten(td, [e for _, e in pairs])
        return wire, new_ef

    def dec(self, wire, proto):
        """wire tree -> value tree (dtype/shape of ``proto``)."""
        if self.codec == "fp32":
            return wire
        leaves_p, td = jax.tree_util.tree_flatten(proto)
        leaves_w = td.flatten_up_to(wire)

        def one(w, p):
            if not _float_leaf(p):
                return w
            if self.codec == "bf16":
                return w.astype(jnp.dtype(p.dtype))
            n, _ = self._q_shapes(p)
            flat = _dequantize_block(w["q"], w["s"], n)
            return flat.reshape(tuple(p.shape)).astype(jnp.dtype(p.dtype))
        return jax.tree_util.tree_unflatten(
            td, [one(w, p) for w, p in zip(leaves_w, leaves_p)])


# ---------------------------------------------------------------------------
# THE schedule executor — the repo's single tick loop
# ---------------------------------------------------------------------------

def run_pipeline_tasks(stage_apply: StageApplyFn,
                       stage_params,
                       inputs_mb,
                       cfg: ParallelConfig,
                       *,
                       tplan: plan_lib.TaskPlan,
                       head_params=None,
                       loss_args_mb=None,
                       loss_fn=None,
                       skip_protos: Optional[Dict[str, Any]] = None,
                       resident=None,
                       carry_proto=None,
                       axis: str = PIPE_AXIS,
                       rank=None,
                       loss_scale: float = 1.0,
                       resid_info: Optional[Dict[str, Any]] = None):
    """Execute one event plan (forward-only, or fused F+B) for a mini-batch.

    Forward-only plans (``tplan.has_backward == False``) return
    ``(outputs, resident)``: outputs is the ``[m, ...carry]`` collection at
    the last rank (autodiff through this call induces the reverse
    clock-cycle).  F+B plans return ``(loss_sum, stage_grads, head_grads,
    input_grads_mb, resident)``: a backward tick re-reads the parked
    boundary activation and skip operands, recomputes the stage forward
    inside ``jax.vjp`` (the paper's Checkpoint/Recompute pairing, now
    structural), and ships carry / skip cotangents down the reverse
    routes.  Fused B ticks produce input and weight cotangents together;
    split plans run Bx (inputs only) on the critical path and Bw (weights
    only) in former bubble ticks, re-seeding the weight VJP from the
    still-parked output cotangent.

    Split plans lowered with ``residuals="reuse"`` (true ZB-H1) change the
    Bw story: the Bx tick vjp's the remat-policy-wrapped stage over ALL
    arguments, ships the input cotangents, and *stashes* the pullback's
    residual leaves (minus the ones rederivable from live state — parked
    inputs, params, labels) into the plan-allocated residual slot; the Bw
    tick rebuilds the pullback around the stashed leaves, so its local
    forward recompute is dead code XLA eliminates — Bw costs one forward
    of work (the weight-grad half) instead of two.  ``cfg.remat`` decides
    what the pullback saves and hence what is stashed
    (:mod:`repro.core.checkpointing`).  Pass a dict as ``resid_info`` to
    receive the stash geometry (leaf shapes, bytes per slot) observed at
    trace time.

    With interleaved plans (``tplan.n_chunks > 1``), ``stage_params``
    leaves carry a leading ``[n_chunks]`` axis — rank ``r`` holds global
    stages ``{r, r + R, ...}`` — and each task dynamically selects its
    chunk; returned ``stage_grads`` mirror the ``[n_chunks, ...]`` block.

    The plan's segments drive one scan each: a GPipe fill runs a pure-F
    loop with no gradient bookkeeping at all, the 1F1B steady state runs
    the mixed F/B loop, and a ZB drain runs Bw-only ticks — the
    ``lax.switch`` in each segment contains exactly the branches that
    segment uses.

    ``cfg.executor`` picks the lowering of each segment:

    * ``"spmd"`` (reference): every rank traces the segment's UNION
      branch set, gathers its plan columns with a dynamic ``[axis_index]``
      read, and ships its boundary output eagerly at the end of each tick
      (compute -> send serialized).
    * ``"mpmd"``: a top-level rank-indexed ``lax.switch`` dispatches one
      specialized tick body per rank — static column reads, branch sets
      pruned to exactly the kinds that rank's column contains in the
      segment (``plan.specialize``'s projection; a rank that is all-F in
      a window runs branch-free code), buffer writes elided where that
      rank's columns prove them dead — and the chain ``ppermute`` is
      double-buffered: a tick's boundary output latches into a send
      register (``plan.send_slot``) and ships at the TOP of the next
      tick, so the hop has no data dependency on that tick's compute and
      overlaps it (``optimization_barrier`` pins the grouping).  The
      collective skeleton stays rank-uniform outside the switch —
      collectives inside per-rank branches would deadlock a real device
      group — and one SPMD executable still allocates ring-max buffers;
      the per-rank programs *declare* their true footprint
      (``plan.specialize(tplan, r).buffer_slots()``), which bench/dryrun
      report.  Identical values flow on identical ticks, so both
      executors are bitwise-identical in loss and gradients.

    Losses accumulate in ascending micro order on the last stage
    (identical in every schedule) and parameter cotangents are collected
    per-micro and reduced in a fixed order (``cfg.grad_reduce ==
    "ordered"``), so any two schedules of the same computation produce
    bitwise-identical losses and gradients.  ``grad_reduce == "running"``
    instead folds cotangents in schedule order — O(1) extra memory, but
    bit-exact only against itself.
    """
    R, m = cfg.pipe, cfg.n_micro
    assert tplan.n_ranks == R and tplan.n_micro == m
    v = tplan.n_chunks
    chunked = v > 1
    fb = tplan.has_backward
    if rank is not None:
        idx = rank
    else:
        idx = jax.lax.axis_index(axis) if R > 1 else jnp.zeros((), jnp.int32)
    skip_protos = skip_protos or {}
    resident = {} if resident is None else resident
    routes = tplan.routes
    skip_names = tuple(dict.fromkeys(rt.name for rt in routes))
    for name in skip_names:
        if name not in skip_protos:
            raise ValueError(f"skip edge {name!r} has no proto")
    streaming = cfg.stream_inputs and R > 1
    k_stream = m // R if streaming else 0
    mpmd = cfg.executor == "mpmd"

    # on-the-wire codec per payload class (plan.TaskPlan.wire): chain
    # carries, portal/skip route values, and backward cotangents (chain +
    # mirrored route cotangents) each pick fp32 | bf16 | int8-ef.
    wire_spec = tplan.wire
    cdc_id = _Codec("fp32", wire_spec.block)
    cdc_chain = _Codec(wire_spec.chain, wire_spec.block)
    cdc_portal = _Codec(wire_spec.portal, wire_spec.block)
    cdc_cot = _Codec(wire_spec.cotangent, wire_spec.block)
    if R == 1:
        # single-rank pipelines have no chain wire: the "hop" is an
        # identity hold, never lossified
        cdc_chain = cdc_cot = cdc_id
    # route payloads: the codec applies only where the hop actually
    # crosses a wire (non-empty permute); same-rank holds stay exact
    rt_vc = {rt.key: (cdc_portal if rt.fwd_perm else cdc_id)
             for rt in routes}
    rt_gc = {rt.key: (cdc_cot if rt.bwd_perm else cdc_id)
             for rt in routes}
    wire_stateful = (cdc_chain.stateful or cdc_cot.stateful
                     or any(c.stateful for c in rt_vc.values())
                     or any(c.stateful for c in rt_gc.values()))

    if fb:
        if loss_fn is None:
            raise ValueError("F+B plans need a loss_fn")
        if cfg.grad_reduce not in ("ordered", "running"):
            raise ValueError(f"unknown grad_reduce {cfg.grad_reduce!r}; "
                             "want 'ordered' or 'running'")
        ordered = cfg.grad_reduce == "ordered"
        seed = jnp.asarray(loss_scale / m, jnp.float32)

    if carry_proto is None:
        carry0 = jax.tree.map(lambda a: jnp.zeros(a.shape[1:], a.dtype),
                              inputs_mb)
    else:
        carry0 = _zeros_of(carry_proto)
    fresh0 = jax.tree.map(lambda a: jnp.zeros(a.shape[1:], a.dtype),
                          inputs_mb)
    is_last_rank = idx == R - 1

    def chunk_params(p_all, c):
        if not chunked:
            return p_all
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, c, 0, keepdims=False),
            p_all)

    # ---- scan state (identical pytree across all segment scans) -----------
    # Chain registers and route registers/in-flight values live in WIRE
    # format (the fp32 codec's wire format IS the value format): MPMD route
    # payloads latch into "snd"/"gsnd" registers shipped at the top of the
    # next tick (double-buffered like the chain carry); SPMD keeps the
    # eager end-of-tick "fly"/"gfly" in-flight slots.
    route_reg = "snd" if mpmd else "fly"
    g_route_reg = "gsnd" if mpmd else "gfly"
    st = {
        "f_chain": cdc_chain.zeros(carry0),
        "park": _buf(max(tplan.park_depth, 1), carry0),
        "resident": resident,
        "routes": {rt.key: {"buf": _buf(rt.depth, skip_protos[rt.name]),
                            route_reg: rt_vc[rt.key].zeros(
                                skip_protos[rt.name])}
                   for rt in routes},
    }
    if streaming:
        st["stream"] = inputs_mb
    if fb:
        st["b_chain"] = cdc_cot.zeros(carry0)
        st["b_inbox"] = _buf(tplan.b_inbox_depth, carry0)
        st["loss"] = jnp.zeros((), jnp.float32)
        st["g_stage"] = (_buf(m, stage_params) if ordered
                         else jax.tree.map(jnp.zeros_like, stage_params))
        st["g_head"] = (_buf(m, head_params) if ordered
                        else jax.tree.map(jnp.zeros_like, head_params))
        st["igbuf"] = _buf(m, fresh0)
        if streaming:
            st["fs"] = _buf(tplan.fs_depth, fresh0)
        for rt in routes:
            st["routes"][rt.key]["gbuf"] = _buf(rt.g_depth,
                                                skip_protos[rt.name])
            st["routes"][rt.key][g_route_reg] = rt_gc[rt.key].zeros(
                skip_protos[rt.name])
    if wire_stateful:
        # per-(rank, stream) error-feedback state for int8-ef classes; the
        # residual of each real send folds into the next payload of the
        # same stream (chain, backward chain, each route's value /
        # cotangent flow)
        wef: Dict[str, Any] = {}
        if cdc_chain.stateful:
            wef["f"] = cdc_chain.ef_zeros(carry0)
        if fb and cdc_cot.stateful:
            wef["b"] = cdc_cot.ef_zeros(carry0)
        for rt in routes:
            if rt_vc[rt.key].stateful:
                wef["r:" + rt.key] = rt_vc[rt.key].ef_zeros(
                    skip_protos[rt.name])
            if fb and rt_gc[rt.key].stateful:
                wef["g:" + rt.key] = rt_gc[rt.key].ef_zeros(
                    skip_protos[rt.name])
        st["wef"] = wef
    if not fb:
        st["outputs"] = _buf(m, carry0)
        # the stream shard's batch dim is also at 1 ([k, mb, ...]), so one
        # constraint covers both input modes before slicing / rotating.
        inputs_mb = _constrain_batch0(inputs_mb, lead=1)
        if streaming:
            st["stream"] = inputs_mb

    def normalize_skips(skips_out):
        """Stage skips_out -> exactly the declared names (protos' dtypes)."""
        out = {}
        for name in skip_names:
            proto = skip_protos[name]
            if skips_out and name in skips_out:
                out[name] = jax.tree.map(
                    lambda v, p: v.astype(p.dtype), skips_out[name], proto)
            else:
                out[name] = _zeros_of(proto)
        return out

    def zeros_skips():
        return {name: _zeros_of(skip_protos[name]) for name in skip_names}

    # ---- residual reuse (ZB-H1): probe the stash geometry ----------------
    reuse = fb and tplan.residuals == "reuse"
    stash_mask: Tuple[bool, ...] = ()
    stash_protos: list = []
    if resid_info is not None and not reuse:
        resid_info.update(residuals="recompute", resid_depth=0,
                          per_stage_resid=[], resid_leaves=[],
                          resid_bytes_per_slot=0)

    def stage_core(p_all, c, si, fr, ph,
                   micro_t, chunk_t, t, is_last_stage, resident_t, largs_t):
        """THE stage+loss body every F+B tick runs — forward ticks, fused
        backwards, and both split-backward halves differentiate exactly
        this one definition (``apply_full`` and ``make_full_f`` are thin
        adapters), so the reuse path can never drift from the forward."""
        p = chunk_params(p_all, chunk_t)
        gstage = chunk_t * R + idx if chunked else idx
        ctx = TickCtx(stage=gstage, micro=micro_t,
                      valid=jnp.asarray(True), t=t, fresh=fr,
                      n_stages=tplan.n_stages, n_micro=m)
        carry_out, skips_out, res_new = stage_apply(p, c, si, resident_t, ctx)
        if not cfg.overlap:
            (carry_out,), = (_barrier(carry_out),)
        loss_i = jax.lax.cond(
            is_last_stage,
            lambda: loss_fn(ph, carry_out, largs_t).astype(jnp.float32),
            lambda: jnp.zeros((), jnp.float32))
        return carry_out, normalize_skips(skips_out), loss_i, res_new

    def make_full_f(micro_t, chunk_t, t, is_last_stage, resident_t, largs_t):
        """The function split-backward ticks differentiate: identical
        structure for the Bx tick (input half + residual stash), the Bw
        tick (weight half from stashed residuals), and the setup probe
        below — all three traces must produce the same pullback leaf
        list, which the in-branch ``stash_mask`` asserts.
        """
        def f(p_all, c, si, fr, ph):
            carry_out, skips, loss_i, _ = stage_core(
                p_all, c, si, fr, ph,
                micro_t, chunk_t, t, is_last_stage, resident_t, largs_t)
            return carry_out, skips, loss_i
        return checkpointing.wrap_for_residuals(
            f, cfg.remat, "reuse" if reuse else "recompute")

    if reuse:
        largs_proto = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(tuple(a.shape[1:]), a.dtype),
            loss_args_mb)
        i32 = jax.ShapeDtypeStruct((), jnp.int32)
        bool_ = jax.ShapeDtypeStruct((), jnp.bool_)
        probe_out = {}

        def probe(p_all, c, si, fr, ph, res_t, la, mi, ch, tt, last):
            f = make_full_f(mi, ch, tt, last, res_t, la)
            _, _, leaves, _, mask = _vjp_split(
                f, (p_all, c, si, fr, ph),
                also_live=(res_t, la, mi, ch, tt, last, idx))
            probe_out["mask"] = mask
            return [l for l, keep in zip(leaves, mask) if keep]

        stash_protos = list(jax.eval_shape(
            probe, stage_params, carry0, zeros_skips(), fresh0, head_params,
            resident, largs_proto, i32, i32, i32, bool_))
        stash_mask = probe_out["mask"]
        if resid_info is not None:
            resid_info.update(
                residuals="reuse", remat=cfg.remat,
                resid_depth=tplan.resid_depth,
                per_stage_resid=list(tplan.per_stage_resid),
                resid_leaves=[(tuple(p.shape), str(jnp.dtype(p.dtype)))
                              for p in stash_protos],
                resid_bytes_per_slot=sum(
                    int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
                    for p in stash_protos))
        if stash_protos:
            st["resid"] = [
                jnp.zeros((max(tplan.resid_depth, 1),) + tuple(p.shape),
                          jnp.dtype(p.dtype)) for p in stash_protos]
    has_stash = bool(stash_protos)

    # ---- per-segment scan bodies -----------------------------------------
    # Both executors share one tick body (`rank_tick`): the SPMD reference
    # path calls it once with dynamic rank indexing and the segment's UNION
    # branch set; the MPMD path dispatches R specialized instances — static
    # column reads, per-rank pruned branch sets and buffer-write elision —
    # under a single top-level rank-indexed lax.switch.  Collectives (chain
    # permutes, route hops, stream rotation) always stay in the rank-uniform
    # skeleton OUTSIDE that switch: a collective inside a per-rank branch
    # would deadlock a real device group.
    # global ship mask: tick t's skeleton permute carries the latches
    # written at t-1 (MPMD double buffering, see plan.py)
    ship_f_tick = np.zeros(tplan.n_ticks, bool)
    ship_b_tick = np.zeros(tplan.n_ticks, bool)
    ship_f_tick[1:] = (tplan.send_slot[:-1] >= 0).any(axis=1)
    ship_b_tick[1:] = (tplan.b_send_slot[:-1] >= 0).any(axis=1)
    route_name_of = {rt.key: rt.name for rt in routes}

    def make_segment(seg: plan_lib.Segment):
        sl = slice(seg.start, seg.stop)
        kinds = seg.kinds
        has_f = FWD in kinds
        has_bi = any(k in kinds for k in plan_lib.BWD_INPUT_KINDS)
        has_bw = any(k in kinds for k in plan_lib.BWD_WEIGHT_KINDS)
        need_park = bool((tplan.park_recv[sl] >= 0).any())
        need_bseed = fb and bool((tplan.b_read[sl] >= 0).any())
        need_brecv = fb and bool((tplan.b_recv[sl] >= 0).any())
        need_rot = streaming and bool(tplan.stream_rot[sl].any())
        need_x = bool((tplan.park_read[sl] >= 0).any())
        has_rx = reuse and has_stash and BWD_X in kinds
        need_rw = has_rx and bool((tplan.resid_write[sl] >= 0).any())
        need_rd = reuse and has_stash \
            and bool((tplan.resid_read[sl] >= 0).any())
        # MPMD: does any tick of this segment ship a latched chain value?
        # (an arrival implies a ship one tick earlier, so need_park /
        # need_brecv can never outrun these)
        need_ship_f = mpmd and bool(ship_f_tick[sl].any())
        need_ship_b = mpmd and fb and bool(ship_b_tick[sl].any())
        # per-route ship masks (MPMD latched routes) and arrival flags —
        # a route arrival in a segment implies a ship tick in the same
        # segment (the latch is always exactly one tick earlier)
        rship = {rt.key: mpmd and bool(rt.ship[sl].any()) for rt in routes}
        rgship = {rt.key: mpmd and fb and bool(rt.g_ship[sl].any())
                  for rt in routes}
        seg_recv = {rt.key: bool((rt.recv[sl] >= 0).any()) for rt in routes}
        seg_grecv = {rt.key: fb and bool((rt.g_recv[sl] >= 0).any())
                     for rt in routes}
        if mpmd:
            assert not need_park or need_ship_f
            assert not need_brecv or need_ship_b
            for rt in routes:
                assert not seg_recv[rt.key] or rship[rt.key], \
                    f"route {rt.key}: arrival without a same-segment ship"
                assert not seg_grecv[rt.key] or rgship[rt.key], \
                    f"route {rt.key}: g arrival without a same-segment ship"

        # per-rank specialization tables (MPMD): rank r's branch set over
        # this segment is EXACTLY the kinds its column contains here
        if mpmd:
            rank_kinds = tuple(
                tuple(sorted(set(int(k) for k in tplan.kind[sl, r])))
                for r in range(R))
        else:
            rank_kinds = (kinds,) * R

        # branch-index remap: plan kind id -> position in the executing
        # branch set (per rank under MPMD, the union set under SPMD)
        sel = tplan.kind[sl].copy()
        for r in range(R):
            remap_r = {k: i for i, k in enumerate(rank_kinds[r])}
            for k, i in remap_r.items():
                sel[tplan.kind[sl, r] == k, r] = i

        xs = {
            "t": jnp.arange(seg.start, seg.stop),
            "sel": jnp.asarray(sel),
            "micro": jnp.asarray(tplan.micro[sl]),
            "chunk": jnp.asarray(tplan.chunk[sl]),
            "prd": jnp.asarray(tplan.park_read[sl]),
        }
        if need_park:
            xs["prs"] = jnp.asarray(tplan.park_recv[sl])
        if need_bseed:
            xs["brd"] = jnp.asarray(tplan.b_read[sl])
        if need_brecv:
            xs["brs"] = jnp.asarray(tplan.b_recv[sl])
        if need_rw:
            xs["rw"] = jnp.asarray(tplan.resid_write[sl])
        if need_rd:
            xs["rd"] = jnp.asarray(tplan.resid_read[sl])
        # "snd"/"bsnd" drive the MPMD latches — and, under a stateful
        # chain/cotangent codec, the SPMD eager sends' EF gating (the EF
        # update must key on the same real-send predicate in both
        # executors to keep them bitwise-identical in lossy modes)
        if (mpmd or cdc_chain.stateful) and has_f:
            xs["snd"] = jnp.asarray(tplan.send_slot[sl])
        if (mpmd or cdc_cot.stateful) and fb and has_bi:
            xs["bsnd"] = jnp.asarray(tplan.b_send_slot[sl])
        if streaming:
            xs["ssl"] = jnp.asarray(tplan.stream_slot[sl])
            xs["rot"] = jnp.asarray(tplan.stream_rot[sl])
            if fb:
                xs["fsl"] = jnp.asarray(tplan.fs_slot[sl])
        rxs = {}
        for rt in routes:
            e = {}
            for nm, arr in (("send", rt.send), ("recv", rt.recv),
                            ("read", rt.read)):
                if (arr[sl] >= 0).any() or (nm == "send"
                                            and (arr[sl] != -1).any()):
                    e[nm] = jnp.asarray(arr[sl])
            if fb:
                for nm, arr in (("g_send", rt.g_send), ("g_recv", rt.g_recv),
                                ("g_read", rt.g_read)):
                    if (arr[sl] >= 0).any() or (nm == "g_send"
                                                and (arr[sl] != -1).any()):
                        e[nm] = jnp.asarray(arr[sl])
            rxs[rt.key] = e
        if rxs and any(rxs.values()):
            xs["routes"] = rxs

        def rank_tick(r, st, xt, arr_f, arr_b, arr_rt, arr_grt):
            """One rank's tick: arrivals -> operands -> task -> commit.

            ``r is None`` is the SPMD reference instance: dynamic
            ``[idx]`` column reads and the segment's union branch set.  A
            static ``r`` is rank r's MPMD specialization: static column
            reads, branch set pruned to exactly the kinds rank r runs in
            this segment (a single kind dispatches with no switch at
            all), and buffer writes elided when rank r's columns prove
            them dead.  ``arr_f`` / ``arr_b`` are this tick's chain
            arrivals (SPMD: the value permuted at the end of last tick;
            MPMD: the latch register shipped at the top of this one);
            ``arr_rt`` / ``arr_grt`` are the route value / cotangent
            arrivals keyed by route, already wire-decoded by the
            skeleton.  Returns ``(out_state, extras)`` with ``extras``
            rank-uniform.
            """
            static = r is not None

            def col(a):
                return a[r] if static else a[idx]

            if static:
                kinds_r = rank_kinds[r]
                csl = (sl, r)
                r_park = need_park and bool(
                    (tplan.park_recv[csl] >= 0).any())
                r_bseed = need_bseed and bool((tplan.b_read[csl] >= 0).any())
                r_brecv = need_brecv and bool((tplan.b_recv[csl] >= 0).any())
                r_x = need_x and bool((tplan.park_read[csl] >= 0).any())
                r_rx = reuse and has_stash and BWD_X in kinds_r
                r_rw = need_rw and bool((tplan.resid_write[csl] >= 0).any())
                r_rd = need_rd and bool((tplan.resid_read[csl] >= 0).any())
                r_latch_f = has_f and bool((tplan.send_slot[csl] >= 0).any())
                r_latch_b = fb and has_bi and bool(
                    (tplan.b_send_slot[csl] >= 0).any())
            else:
                kinds_r = kinds
                r_park, r_bseed, r_brecv = need_park, need_bseed, need_brecv
                r_x, r_rx, r_rw, r_rd = need_x, has_rx, need_rw, need_rd
                r_latch_f = r_latch_b = False
            r_f = FWD in kinds_r
            r_bi = any(k in kinds_r for k in plan_lib.BWD_INPUT_KINDS)
            r_bw = any(k in kinds_r for k in plan_lib.BWD_WEIGHT_KINDS)
            r_b = any(k in kinds_r for k in plan_lib.BWD_KINDS)
            remap = {k: i for i, k in enumerate(kinds_r)}

            t = xt["t"]
            sel_t = col(xt["sel"])
            micro_t = col(xt["micro"])
            chunk_t = col(xt["chunk"])
            prd = col(xt["prd"])
            is_last_stage = (is_last_rank & (chunk_t == v - 1) if chunked
                             else is_last_rank)

            # 1. park ring / route arrivals in their plan-assigned slots
            park = st["park"]
            if r_park:
                prs = col(xt["prs"])
                park = _masked_write(park, arr_f, prs, prs >= 0)
            rst = {}
            for rt in routes:
                rx = xt.get("routes", {}).get(rt.key, {})
                rs = st["routes"][rt.key]
                entry = {"buf": rs["buf"], route_reg: rs[route_reg]}
                if "recv" in rx:
                    rc = col(rx["recv"])
                    entry["buf"] = _masked_write(rs["buf"],
                                                 arr_rt[rt.key], rc,
                                                 rc >= 0)
                if fb:
                    entry["gbuf"] = rs["gbuf"]
                    entry[g_route_reg] = rs[g_route_reg]
                    if "g_recv" in rx:
                        grc = col(rx["g_recv"])
                        entry["gbuf"] = _masked_write(rs["gbuf"],
                                                      arr_grt[rt.key],
                                                      grc, grc >= 0)
                rst[rt.key] = entry
            b_inbox = st.get("b_inbox")
            if r_brecv:
                brs = col(xt["brs"])
                b_inbox = _masked_write(b_inbox, arr_b, brs, brs >= 0)

            # 2. gather this tick's operands
            if r_x:
                x_f = _select(prd >= 0, _dyn_read(park, prd),
                              _zeros_of(carry0))
            else:
                x_f = _zeros_of(carry0)
            if not fb:
                x_f = _constrain_batch0(x_f)
            skips_in = zeros_skips()
            for rt in routes:
                rx = xt.get("routes", {}).get(rt.key, {})
                if "read" in rx:
                    rd = col(rx["read"])
                    skips_in[rt.name] = _select(
                        rd >= 0, _dyn_read(rst[rt.key]["buf"], rd),
                        skips_in[rt.name])
            if streaming:
                ssl = jnp.clip(xt["ssl"], 0, max(k_stream - 1, 0))
                fresh_f = _dyn_read(st["stream"], ssl)
            else:
                fresh_f = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, micro_t, 0, keepdims=False), inputs_mb)
                if not fb:
                    fresh_f = _constrain_batch0(fresh_f)
            resident = st["resident"]

            if fb:
                if r_bseed:
                    brd = col(xt["brd"])
                    bseed = _select(brd >= 0, _dyn_read(b_inbox, brd),
                                    _zeros_of(carry0))
                else:
                    bseed = _zeros_of(carry0)
                if streaming and r_b:
                    fsl = col(xt["fsl"])
                    fresh_b = _dyn_read(st["fs"], fsl)
                else:
                    fresh_b = fresh_f
                largs = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, micro_t, 0, keepdims=False), loss_args_mb)
                skip_seeds = zeros_skips()
                for rt in routes:
                    rx = xt.get("routes", {}).get(rt.key, {})
                    if "g_read" in rx:
                        gr = col(rx["g_read"])
                        add = _select(gr >= 0,
                                      _dyn_read(rst[rt.key]["gbuf"], gr),
                                      _zeros_of(skip_protos[rt.name]))
                        skip_seeds[rt.name] = jax.tree.map(
                            jnp.add, skip_seeds[rt.name], add)
                if r_rd:
                    rd = col(xt["rd"])
                    resid_in = [
                        _select(rd >= 0, _dyn_read(bufl, rd),
                                jnp.zeros(bufl.shape[1:], bufl.dtype))
                        for bufl in st["resid"]]
                else:
                    # a coalesced segment may carry the BWD_W branch without
                    # any Bw tick in its slice: the branch still traces, so
                    # feed it (dead) zeros of the stash leaves
                    resid_in = [jnp.zeros(tuple(p.shape), jnp.dtype(p.dtype))
                                for p in stash_protos]

            # 3. run exactly one task (XLA conditional: no masked work)
            if fb:
                def apply_full(p_all, c, si, fr, ph):
                    return stage_core(p_all, c, si, fr, ph,
                                      micro_t, chunk_t, t, is_last_stage,
                                      resident, largs)

                def out_zeros():
                    o = {"res": resident}
                    if r_f:
                        o["carry"] = _zeros_of(carry0)
                        o["skips"] = zeros_skips()
                        o["loss"] = jnp.zeros((), jnp.float32)
                    if r_bi:
                        o["b"] = _zeros_of(carry0)
                        o["gskips"] = zeros_skips()
                        o["g_fr"] = _zeros_of(fresh0)
                    if r_bw:
                        o["g_p"] = jax.tree.map(jnp.zeros_like, stage_params)
                        o["g_ph"] = jax.tree.map(jnp.zeros_like, head_params)
                    if r_rx:
                        o["resid"] = [jnp.zeros(tuple(p.shape),
                                                jnp.dtype(p.dtype))
                                      for p in stash_protos]
                    return o

                def seeds_tuple():
                    loss_bar = jnp.where(is_last_stage, seed,
                                         0.0).astype(jnp.float32)
                    return bseed, skip_seeds, loss_bar

                def nop_branch():
                    return out_zeros()

                def f_branch():
                    carry_out, skip_vals, loss_i, res_new = apply_full(
                        stage_params, x_f, skips_in, fresh_f, head_params)
                    o = out_zeros()
                    o.update(carry=carry_out, skips=skip_vals, loss=loss_i,
                             res=res_new)
                    return o

                def b_branch():
                    def f(p, c, si, fr, ph):
                        carry_out, skip_vals, loss_i, _ = apply_full(
                            p, c, si, fr, ph)
                        return carry_out, skip_vals, loss_i
                    # jax.vjp recomputes the stage forward from the parked
                    # boundary input + parked skip operands and applies the
                    # cotangents immediately — remat-before-backward with no
                    # residuals carried across ticks.
                    _, vjp = jax.vjp(f, stage_params, x_f, skips_in, fresh_b,
                                     head_params)
                    g_p, g_c, g_si, g_fr, g_ph = vjp(seeds_tuple())
                    o = out_zeros()
                    o.update(b=g_c, gskips=g_si, g_fr=g_fr, g_p=g_p,
                             g_ph=g_ph)
                    return o

                def bx_branch():
                    def f(c, si, fr):
                        carry_out, skip_vals, loss_i, _ = apply_full(
                            stage_params, c, si, fr, head_params)
                        return carry_out, skip_vals, loss_i
                    # input-cotangent half only: weight-gradient chains are
                    # dead code here and XLA eliminates them.
                    _, vjp = jax.vjp(f, x_f, skips_in, fresh_b)
                    g_c, g_si, g_fr = vjp(seeds_tuple())
                    o = out_zeros()
                    o.update(b=g_c, gskips=g_si, g_fr=g_fr)
                    return o

                def bw_branch():
                    def f(p, ph):
                        carry_out, skip_vals, loss_i, _ = apply_full(
                            p, x_f, skips_in, fresh_b, ph)
                        return carry_out, skip_vals, loss_i
                    # weight-gradient half, re-seeded from the parked output
                    # cotangent; input chains are dead code.
                    _, vjp = jax.vjp(f, stage_params, head_params)
                    g_p, g_ph = vjp(seeds_tuple())
                    o = out_zeros()
                    o.update(g_p=g_p, g_ph=g_ph)
                    return o

                if reuse:
                    # True ZB-H1 residual reuse: Bx vjp's the policy-wrapped
                    # stage over ALL args, ships the input cotangents and
                    # stashes the pullback's non-rederivable leaves; Bw
                    # rebuilds the pullback around the stashed leaves, so
                    # its own recompute is dead code XLA eliminates.
                    full_args = (stage_params, x_f, skips_in, fresh_b,
                                 head_params)

                    # rederivable-at-Bw values (same micro, same rank, the
                    # tick scalars, labels, resident state) are excluded
                    # from the stash: the Bw tick substitutes its own live
                    # copies, exactly as recompute-mode semantics would.
                    rederivable = (resident, largs, micro_t, chunk_t, t,
                                   is_last_stage, idx)

                    def bx_branch():
                        f = make_full_f(micro_t, chunk_t, t, is_last_stage,
                                        resident, largs)
                        _, vjp_fn, leaves, _, mask = _vjp_split(
                            f, full_args, also_live=rederivable)
                        assert mask == stash_mask, \
                            "Bx residual structure diverged from the probe"
                        _, g_c, g_si, g_fr, _ = vjp_fn(seeds_tuple())
                        o = out_zeros()
                        o.update(b=g_c, gskips=g_si, g_fr=g_fr)
                        if r_rx:
                            o["resid"] = [l for l, keep in zip(leaves, mask)
                                          if keep]
                        return o

                    def bw_branch():
                        f = make_full_f(micro_t, chunk_t, t, is_last_stage,
                                        resident, largs)
                        _, _, leaves, treedef, mask = _vjp_split(
                            f, full_args, also_live=rederivable)
                        assert mask == stash_mask, \
                            "Bw residual structure diverged from the probe"
                        it = iter(resid_in)
                        merged = [next(it) if keep else leaf
                                  for leaf, keep in zip(leaves, mask)]
                        vjp2 = jax.tree_util.tree_unflatten(treedef, merged)
                        g_p, _, _, _, g_ph = vjp2(seeds_tuple())
                        o = out_zeros()
                        o.update(g_p=g_p, g_ph=g_ph)
                        return o

                branch_of = {NOP: nop_branch, FWD: f_branch, BWD: b_branch,
                             BWD_X: bx_branch, BWD_W: bw_branch}
                branches = tuple(branch_of[k] for k in kinds_r)
                res = (branches[0]() if len(branches) == 1
                       else jax.lax.switch(sel_t, branches))
            else:
                ctx = TickCtx(stage=idx, micro=micro_t, valid=sel_t
                              == remap.get(FWD, -1), t=t, fresh=fresh_f,
                              n_stages=tplan.n_stages, n_micro=m)
                wrapped = checkpointing.wrap_stage(
                    lambda p, c, si, rr: stage_apply(p, c, si, rr, ctx),
                    cfg.remat)

                def nop_branch():
                    return {"carry": _zeros_of(carry0),
                            "skips": zeros_skips(), "res": resident}

                def f_branch():
                    carry_out, skips_out, res_new = wrapped(
                        stage_params, x_f, skips_in, resident)
                    if not cfg.overlap:
                        (carry_out,), = (_barrier(carry_out),)
                    return {"carry": _constrain_batch0(carry_out),
                            "skips": normalize_skips(skips_out),
                            "res": res_new}

                branch_of = {NOP: nop_branch, FWD: f_branch}
                branches = tuple(branch_of[k] for k in kinds_r)
                res = (branches[0]() if len(branches) == 1
                       else jax.lax.switch(sel_t, branches))

            # 4. commit state
            out = dict(st)
            out["park"] = park
            out["resident"] = res["res"]
            wef = dict(st["wef"]) if wire_stateful else None
            is_f = sel_t == remap.get(FWD, -1) if r_f else None
            if fb:
                if r_f:
                    out["loss"] = st["loss"] + res["loss"]
                    if streaming:
                        fsl = col(xt["fsl"])
                        out["fs"] = _masked_write(st["fs"], fresh_f, fsl,
                                                  is_f & (fsl >= 0))
                if r_bw:
                    w_sels = [remap[k] for k in plan_lib.BWD_WEIGHT_KINDS
                              if k in remap]
                    is_w = functools.reduce(
                        jnp.logical_or, [sel_t == s for s in w_sels])
                    if ordered:
                        wr = _masked_accum if chunked else _masked_write
                        out["g_stage"] = wr(st["g_stage"], res["g_p"],
                                            micro_t, is_w)
                        head_pred = is_w & is_last_stage
                        out["g_head"] = _masked_write(st["g_head"],
                                                      res["g_ph"], micro_t,
                                                      head_pred)
                    else:
                        out["g_stage"] = jax.tree.map(jnp.add, st["g_stage"],
                                                      res["g_p"])
                        out["g_head"] = jax.tree.map(jnp.add, st["g_head"],
                                                     res["g_ph"])
                if r_rw:
                    rw = col(xt["rw"])
                    is_x = sel_t == remap[BWD_X]
                    out["resid"] = _masked_write(st["resid"], res["resid"],
                                                 rw, is_x & (rw >= 0))
                if r_bi:
                    bi_sels = [remap[k] for k in plan_lib.BWD_INPUT_KINDS
                               if k in remap]
                    is_bi = functools.reduce(
                        jnp.logical_or, [sel_t == s for s in bi_sels])
                    ig_pred = is_bi & (idx == 0)
                    if chunked:
                        ig_pred = ig_pred & (chunk_t == 0)
                    out["igbuf"] = _masked_write(st["igbuf"], res["g_fr"],
                                                 micro_t, ig_pred)
                    out["b_inbox"] = b_inbox
                    if r_latch_b:
                        # MPMD: encode + latch the input cotangent into the
                        # send register; the NEXT tick's skeleton ships it.
                        bsnd = col(xt["bsnd"])
                        wire_b, ef2 = cdc_cot.enc(
                            res["b"],
                            wef["b"] if cdc_cot.stateful else (),
                            bsnd >= 0)
                        out["b_chain"] = _select(bsnd >= 0, wire_b,
                                                 st["b_chain"])
                        if cdc_cot.stateful:
                            wef["b"] = ef2
                elif r_brecv:
                    out["b_inbox"] = b_inbox
            else:
                if r_f:
                    out["outputs"] = _constrain_batch0(
                        _masked_write(st["outputs"], res["carry"], micro_t,
                                      is_f & is_last_rank), lead=1)
            if r_latch_f:
                # MPMD: encode + latch this tick's boundary output for the
                # next tick's overlapped ship (see plan.TaskPlan.send_slot)
                snd = col(xt["snd"])
                wire_f, ef2 = cdc_chain.enc(
                    res["carry"],
                    wef["f"] if cdc_chain.stateful else (),
                    snd >= 0)
                out["f_chain"] = _select(snd >= 0, wire_f, st["f_chain"])
                if cdc_chain.stateful:
                    wef["f"] = ef2
            if routes and mpmd:
                # MPMD route latch: encode + park outgoing route payloads in
                # the per-route send registers at the bottom of the tick; the
                # next tick's skeleton ships them overlapped with compute —
                # no route hop ever serializes after its producing task.
                for rt in routes:
                    rx = xt.get("routes", {}).get(rt.key, {})
                    entry = rst[rt.key]
                    proto = skip_protos[rt.name]
                    vc, gc = rt_vc[rt.key], rt_gc[rt.key]
                    if "send" in rx and (
                            not static
                            or bool((rt.send[sl, r] != -1).any())):
                        sv = col(rx["send"])
                        fresh = (res["skips"][rt.name]
                                 if (not fb or r_f) else _zeros_of(proto))
                        raw = _select(sv == plan_lib.SEND_STAGE, fresh,
                                      _dyn_read(entry["buf"], sv))
                        ef = wef["r:" + rt.key] if vc.stateful else ()
                        wire_v, ef2 = vc.enc(raw, ef, sv != -1)
                        entry["snd"] = _select(
                            sv != -1, wire_v, st["routes"][rt.key]["snd"])
                        if vc.stateful:
                            wef["r:" + rt.key] = ef2
                    if fb and "g_send" in rx and (
                            not static
                            or bool((rt.g_send[sl, r] != -1).any())):
                        gv = col(rx["g_send"])
                        gfresh = (res["gskips"][rt.name]
                                  if r_bi else _zeros_of(proto))
                        graw = _select(gv == plan_lib.SEND_STAGE, gfresh,
                                       _dyn_read(entry["gbuf"], gv))
                        gef = wef["g:" + rt.key] if gc.stateful else ()
                        wire_g, gef2 = gc.enc(graw, gef, gv != -1)
                        entry["gsnd"] = _select(
                            gv != -1, wire_g, st["routes"][rt.key]["gsnd"])
                        if gc.stateful:
                            wef["g:" + rt.key] = gef2
            if routes:
                # fresh dict: never mutate st (the MPMD branches all close
                # over the same state dict)
                out["routes"] = {rt.key: rst[rt.key] for rt in routes}
            if wef is not None:
                out["wef"] = wef

            extras = {}
            if routes and not mpmd:
                extras["skips"] = (res["skips"] if r_f and has_f
                                   else zeros_skips())
                if fb and has_bi:
                    extras["gskips"] = (res["gskips"] if r_bi
                                        else zeros_skips())
            if not mpmd:
                if has_f:
                    extras["carry"] = res["carry"]
                if fb and has_bi:
                    extras["b"] = res["b"]
            return out, extras

        def tick_body(st, xt):
            # --- rank-uniform comm skeleton, part 1: chain arrivals -------
            if mpmd:
                # double-buffered ship: the permute reads the latch
                # registers written LAST tick, so it carries no data
                # dependency on this tick's compute — XLA's scheduler can
                # overlap the hop with the stage work below.
                arr_f = (_shift_chain(st["f_chain"], R, axis, ring=chunked)
                         if need_ship_f else cdc_chain.zeros(carry0))
                arr_b = None
                if fb:
                    arr_b = (_shift_chain_rev(st["b_chain"], R, axis,
                                              ring=chunked)
                             if need_ship_b else cdc_cot.zeros(carry0))
                # latched route hops: ship last tick's send registers at
                # the top of this tick, same double-buffer story as the
                # chain carry — no route hop serializes after its producer.
                arr_rt = {rt.key: _route_hop(st["routes"][rt.key]["snd"],
                                             rt.fwd_perm, axis)
                          for rt in routes if rship[rt.key]}
                arr_grt = {rt.key: _route_hop(st["routes"][rt.key]["gsnd"],
                                              rt.bwd_perm, axis)
                           for rt in routes if rgship[rt.key]} if fb else {}
                if cfg.overlap and (need_ship_f or need_ship_b
                                    or arr_rt or arr_grt):
                    # pin the overlap: group the in-flight arrivals into
                    # one scheduling unit issued ahead of the compute, so
                    # the compiler cannot sink the send back behind it
                    # (the serialized story cfg.overlap=False ablates to).
                    if fb:
                        arr_f, arr_b, arr_rt, arr_grt = _barrier(
                            arr_f, arr_b, arr_rt, arr_grt)
                    else:
                        arr_f, arr_rt = _barrier(arr_f, arr_rt)
                # decode at arrival (identity for fp32 wire)
                arr_f = cdc_chain.dec(arr_f, carry0)
                if fb:
                    arr_b = cdc_cot.dec(arr_b, carry0)
                arr_rt = {k: rt_vc[k].dec(v, skip_protos[route_name_of[k]])
                          for k, v in arr_rt.items()}
                arr_grt = {k: rt_gc[k].dec(v,
                                           skip_protos[route_name_of[k]])
                           for k, v in arr_grt.items()}
            else:
                arr_f = cdc_chain.dec(st["f_chain"], carry0)
                arr_b = cdc_cot.dec(st["b_chain"], carry0) if fb else None
                arr_rt = {rt.key: rt_vc[rt.key].dec(
                    st["routes"][rt.key]["fly"], skip_protos[rt.name])
                    for rt in routes if seg_recv[rt.key]}
                arr_grt = {rt.key: rt_gc[rt.key].dec(
                    st["routes"][rt.key]["gfly"], skip_protos[rt.name])
                    for rt in routes if seg_grecv[rt.key]}

            # --- per-rank specialized tick ---------------------------------
            if mpmd and R > 1:
                out, extras = jax.lax.switch(
                    idx, tuple(functools.partial(rank_tick, r)
                               for r in range(R)), st, xt, arr_f, arr_b,
                    arr_rt, arr_grt)
            else:
                out, extras = rank_tick(0 if mpmd else None, st, xt,
                                        arr_f, arr_b, arr_rt, arr_grt)

            # --- rank-uniform comm skeleton, part 2 ------------------------
            # SPMD reference: eager chain sends (this tick's outputs enter
            # the wire immediately, serialized after the compute).
            if not mpmd:
                if fb and has_bi:
                    if cdc_cot.stateful:
                        bsnd = xt["bsnd"][idx]
                        wire_b, ef2 = cdc_cot.enc(extras["b"],
                                                  out["wef"]["b"],
                                                  bsnd >= 0)
                        out["wef"] = dict(out["wef"], b=ef2)
                    else:
                        wire_b, _ = cdc_cot.enc(extras["b"], (), None)
                    out["b_chain"] = _shift_chain_rev(wire_b, R, axis,
                                                      ring=chunked)
                if has_f:
                    if cdc_chain.stateful:
                        snd = xt["snd"][idx]
                        wire_f, ef2 = cdc_chain.enc(extras["carry"],
                                                    out["wef"]["f"],
                                                    snd >= 0)
                        out["wef"] = dict(out["wef"], f=ef2)
                    else:
                        wire_f, _ = cdc_chain.enc(extras["carry"], (), None)
                    out["f_chain"] = _shift_chain(wire_f, R, axis,
                                                  ring=chunked)

            # skip-route hops (static single-pair / chain permutes) — SPMD
            # eager reference: this tick's payload enters the wire
            # immediately, serialized after the compute.  (MPMD latches
            # instead; see the commit section + part-1 skeleton.)
            for rt in (() if mpmd else routes):
                rx = xt.get("routes", {}).get(rt.key, {})
                entry = dict(out["routes"][rt.key])
                if "send" in rx and has_f:
                    sv = rx["send"][idx]
                    val = _select(sv == plan_lib.SEND_STAGE,
                                  extras["skips"][rt.name],
                                  _dyn_read(entry["buf"], sv))
                    vc = rt_vc[rt.key]
                    ef = out["wef"]["r:" + rt.key] if vc.stateful else ()
                    wire_v, ef2 = vc.enc(val, ef, sv != -1)
                    if vc.stateful:
                        out["wef"] = dict(out["wef"],
                                          **{"r:" + rt.key: ef2})
                    entry["fly"] = _route_hop(wire_v, rt.fwd_perm, axis)
                else:
                    entry["fly"] = st["routes"][rt.key]["fly"]
                if fb:
                    if "g_send" in rx and has_bi:
                        gv = rx["g_send"][idx]
                        gval = _select(gv == plan_lib.SEND_STAGE,
                                       extras["gskips"][rt.name],
                                       _dyn_read(entry["gbuf"], gv))
                        gc = rt_gc[rt.key]
                        gef = (out["wef"]["g:" + rt.key]
                               if gc.stateful else ())
                        wire_g, gef2 = gc.enc(gval, gef, gv != -1)
                        if gc.stateful:
                            out["wef"] = dict(out["wef"],
                                              **{"g:" + rt.key: gef2})
                        entry["gfly"] = _route_hop(wire_g, rt.bwd_perm,
                                                   axis)
                    else:
                        entry["gfly"] = st["routes"][rt.key]["gfly"]
                out["routes"][rt.key] = entry

            # rotate the input stream one rank towards stage 0 on the
            # plan-flagged ticks (keeps rotation count == injected micros)
            if need_rot:
                rot = [(i, (i - 1) % R) for i in range(R)]
                spun = jax.tree.map(
                    lambda a: jax.lax.ppermute(a, axis, rot), st["stream"])
                out["stream"] = _select(xt["rot"], spun, st["stream"])
            return out, None

        return xs, tick_body

    state = st
    for seg in tplan.segments:
        xs, body = make_segment(seg)
        if cfg.unroll_ticks:
            for t in range(seg.stop - seg.start):
                state, _ = body(state, jax.tree.map(lambda a, _t=t: a[_t],
                                                    xs))
        else:
            state, _ = jax.lax.scan(body, state, xs)

    if not fb:
        return state["outputs"], state["resident"]
    loss_acc = state["loss"]
    g_stage, g_head, igbuf = state["g_stage"], state["g_head"], state["igbuf"]
    if ordered:
        # fixed-order reduction over the micro axis: the sum is identical
        # for every schedule, making gradients schedule-bitwise-stable.
        g_stage = jax.tree.map(lambda a: jnp.sum(a, axis=0), g_stage)
        g_head = jax.tree.map(lambda a: jnp.sum(a, axis=0), g_head)
    return loss_acc, g_stage, g_head, igbuf, state["resident"]


def run_pipeline(stage_apply: StageApplyFn,
                 stage_params,
                 inputs_mb,
                 cfg: ParallelConfig,
                 *,
                 skips: Sequence[SkipSpec] = (),
                 skip_protos: Optional[Dict[str, Any]] = None,
                 resident=None,
                 carry_proto=None,
                 axis: str = PIPE_AXIS,
                 rank=None):
    """Forward-only wrapper: lower the GPipe clock-cycle plan and run it.

    ``jax.grad`` through this call induces the reverse clock-cycle with
    recompute-before-backward (the legacy semantics); the loop itself is
    :func:`run_pipeline_tasks` on a ``gpipe_fwd`` plan — there is no
    separate forward tick loop any more.

    Returns ``(outputs [m, ...carry], resident)`` — outputs valid on the
    last rank.
    """
    tplan = plan_lib.plan_for("gpipe_fwd", cfg.n_micro, cfg.pipe,
                              skips=skips, portals=cfg.portals,
                              wire=cfg.wire)
    return run_pipeline_tasks(stage_apply, stage_params, inputs_mb, cfg,
                              tplan=tplan, skip_protos=skip_protos,
                              resident=resident, carry_proto=carry_proto,
                              axis=axis, rank=rank)


# ---------------------------------------------------------------------------
# Fused-schedule training entry point (F+B plans)
# ---------------------------------------------------------------------------

def pipeline_grad_call(stage_apply: StageApplyFn,
                       *,
                       mesh: Mesh,
                       cfg: ParallelConfig,
                       loss_fn,
                       carry_proto=None,
                       skips: Sequence[SkipSpec] = (),
                       skip_protos: Optional[Dict[str, Any]] = None,
                       axis: str = PIPE_AXIS,
                       resid_info: Optional[Dict[str, Any]] = None):
    """Build the fused schedule-driven training call.

    Returns ``call(stage_params, head_params, inputs_mb, loss_args_mb,
    resident=None) -> (loss, stage_grads, head_grads, input_grads_mb)``
    where:

    * ``loss`` is the mean per-micro loss (matches ``head_loss`` over the
      full batch up to micro-chunked summation order),
    * ``stage_grads`` mirrors ``stage_params`` ([n_stages, ...], sharded
      over ``pipe``; for interleaved schedules ``n_stages = pipe * v``
      global stages stacked in stage order),
    * ``head_grads`` mirrors ``head_params`` (valid on the last rank),
    * ``input_grads_mb`` mirrors ``inputs_mb`` ([m, ...], valid on rank 0)
      — feed it to the embed VJP outside the pipeline.  Skip cotangents a
      stage-0 producer routes into its fresh input (e.g. the enc-dec
      ``dec_in`` portal) are folded in here as well.

    The schedule comes from ``cfg.schedule``: ``"1f1b"``,
    ``"gpipe"``/``"gpipe_tasked"``, ``"interleaved:v"`` (v virtual stages
    per rank, Megatron-style) or ``"zb"`` (ZB-H1 split backward) — all
    lowered by :func:`repro.core.plan.plan_for` from the validated task
    tables in :mod:`repro.core.schedules`.  Skip edges lower to
    portal/threaded routes per ``cfg.portals``; ``cfg.stream_inputs``
    (with ``m % n == 0``) shards the micro-batches over pipe and injects
    them on plan ticks.  For split-backward schedules,
    ``cfg.residuals="reuse"`` lowers the Bx->Bw residual-stash events
    (true ZB-H1: Bw re-reads what Bx materialized instead of recomputing);
    pass a dict as ``resid_info`` to receive the stash geometry at trace
    time.  ``cfg.executor`` picks the SPMD reference lowering or the MPMD
    per-rank specialization (bitwise-identical; see
    :func:`run_pipeline_tasks`).
    """
    n, m = cfg.pipe, cfg.n_micro
    v = cfg.virtual_stages
    streaming = cfg.stream_inputs and n > 1
    if streaming and m % n:
        # don't silently drop a memory knob: streaming shards the
        # micro-batches over pipe, which needs m % n == 0
        raise ValueError(f"stream_inputs needs n_micro ({m}) divisible by "
                         f"pipe ({n})")
    cfg = cfg.with_(stream_inputs=streaming)
    tplan = plan_lib.plan_for(cfg.schedule, m, n, skips=skips,
                              portals=cfg.portals,
                              residuals=cfg.residuals,
                              wire=cfg.wire)

    def inner(rank_arr, params, head_params, inputs_mb, loss_args_mb,
              bdiv=1, psum_axes=()):
        with compat.manual_region():
            params = jax.tree.map(lambda a: a[0], params)
            if streaming:
                inputs_mb = jax.tree.map(lambda a: a[0], inputs_mb)

            def localize(proto):
                if proto is None or bdiv == 1:
                    return proto
                return jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(
                        (p.shape[0] // bdiv,) + tuple(p.shape[1:]), p.dtype),
                    proto)

            sk_protos = {kk: localize(val)
                         for kk, val in (skip_protos or {}).items()}
            loss_sum, g_stage, g_head, ig, _ = run_pipeline_tasks(
                stage_apply, params, inputs_mb, cfg,
                tplan=tplan, head_params=head_params,
                loss_args_mb=loss_args_mb, loss_fn=loss_fn,
                skip_protos=sk_protos,
                carry_proto=localize(carry_proto), axis=axis,
                rank=rank_arr[0], loss_scale=1.0 / bdiv,
                resid_info=resid_info)
            if psum_axes:
                # batch axes are manual here (old-jax fallback): the DP
                # gradient reduction is explicit.
                loss_sum, g_stage, g_head = jax.lax.psum(
                    (loss_sum, g_stage, g_head), psum_axes)
            loss = loss_sum * (1.0 / (bdiv * m))
            loss = loss[None]
            g_stage = jax.tree.map(lambda a: a[None], g_stage)
            g_head = jax.tree.map(lambda a: a[None], g_head)
            ig = jax.tree.map(lambda a: a[None], ig)
            return loss, g_stage, g_head, ig

    def call(stage_params, head_params, inputs_mb, loss_args_mb):
        rank_arr = jnp.arange(n, dtype=jnp.int32)
        if v > 1:
            # stage-major [n*v, ...] -> rank-major [n, v, ...]: rank r
            # hosts global stages {r, r + n, ...} (Megatron chunk layout)
            stage_params = jax.tree.map(
                lambda a: a.reshape((v, n) + a.shape[1:]).swapaxes(0, 1),
                stage_params)
        if streaming:
            k = m // n
            inputs_mb = jax.tree.map(
                lambda a: a.reshape((k, n) + a.shape[1:]).swapaxes(0, 1),
                inputs_mb)
        if cfg.pipe > 1:
            axis_names = {axis}
            in_spec_x = P(axis) if streaming else P()
            in_spec_l = P()
            out_spec_ig = P(axis)
            bdiv, psum_axes = 1, ()
            if not compat.JAX_HAS_NEW_API:
                # Same old-jax fallback as pipeline_call: fully manual,
                # non-pipe axes become explicit batch parallelism.
                axis_names = set(mesh.axis_names)
                baxes, nd = _oldjax_batch_axes(mesh, axis)
                if nd > 1:
                    bdim_in = 2 if streaming else 1
                    leaves = jax.tree.leaves(inputs_mb)
                    if not (all(l.ndim > bdim_in and l.shape[bdim_in] % nd == 0
                                for l in leaves)
                            and all(l.ndim > 1 and l.shape[1] % nd == 0
                                    for l in jax.tree.leaves(loss_args_mb))):
                        raise _oldjax_divisibility_error(nd)
                    bdiv, psum_axes = nd, baxes
                    in_spec_x = (P(axis, None, baxes) if streaming
                                 else P(None, baxes))
                    in_spec_l = P(None, baxes)
                    out_spec_ig = P(axis, None, baxes)
            fn = shard_map(
                functools.partial(inner, bdiv=bdiv, psum_axes=psum_axes),
                mesh=mesh,
                in_specs=(P(axis), P(axis), P(), in_spec_x, in_spec_l),
                out_specs=(P(axis), P(axis), P(axis), out_spec_ig),
                axis_names=axis_names, check_vma=False)
        else:
            fn = inner
        loss, g_stage, g_head, ig = fn(rank_arr, stage_params, head_params,
                                       inputs_mb, loss_args_mb)
        loss = loss[-1]
        g_head = jax.tree.map(lambda a: a[-1], g_head)
        ig = jax.tree.map(lambda a: a[0], ig)
        if v > 1:
            # rank-major grads [n, v, ...] -> stage-major [n*v, ...]
            g_stage = jax.tree.map(
                lambda a: a.swapaxes(0, 1).reshape((n * v,) + a.shape[2:]),
                g_stage)
        return loss, g_stage, g_head, ig

    return call, tplan


# ---------------------------------------------------------------------------
# shard_map wrapper: the public forward entry point
# ---------------------------------------------------------------------------

def pipeline_call(stage_apply: StageApplyFn,
                  *,
                  mesh: Mesh,
                  cfg: ParallelConfig,
                  skips: Sequence[SkipSpec] = (),
                  skip_protos: Optional[Dict[str, Any]] = None,
                  carry_proto=None,
                  axis: str = PIPE_AXIS):
    """Build ``(stage_params, inputs_mb, resident) -> (outputs, resident)``.

    ``stage_params``/``resident`` leaves carry a leading ``n_stages`` axis
    sharded over ``pipe``; ``inputs_mb`` is replicated over ``pipe`` (its
    batch-ish dims may be sharded over the auto axes).  ``outputs`` gains a
    leading ``pipe``-sharded axis: index ``[-1]`` for the last stage's
    results (:func:`last_stage_output`).

    Forward-only execution always runs the GPipe clock-cycle plan
    (interleaving is a fused-training lever; inference has no backward
    bubble to shrink).
    """
    if cfg.virtual_stages > 1:
        raise ValueError("interleaved schedules are train-only (use "
                         "pipeline_grad_call); forward execution runs the "
                         "clock-cycle plan")
    # Input modes across the shard_map boundary:
    #  * replicated (default): the transpose of the pipe-replicated in_spec
    #    is a psum over the *manual* axis — this both dominates collective
    #    bytes for embedding-fed models AND crashes XLA-CPU's
    #    AllReducePromotion in bf16, so the inputs cross in fp32.
    #  * streaming (cfg.stream_inputs, m % n == 0): micro-batches are
    #    SHARDED over pipe (micro-batch i at rank i%n, slot i//n) and
    #    rotated one hop per plan tick; the transpose is a reverse rotation
    #    (no psum), memory drops by n, and bf16 is safe.
    def inner(rank_arr, params, inputs_mb, resident, in_dtypes, cfg_run,
              bdiv=1):
        def localize(proto):
            # protos describe GLOBAL batch shapes; inside a fully-manual
            # region (old-jax fallback) each rank holds 1/bdiv of the batch.
            if proto is None or bdiv == 1:
                return proto
            return jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(
                    (p.shape[0] // bdiv,) + tuple(p.shape[1:]), p.dtype),
                proto)

        with compat.manual_region():
            params = jax.tree.map(lambda a: a[0], params)
            resident = jax.tree.map(lambda a: a[0], resident)
            if cfg_run.stream_inputs:
                inputs_mb = jax.tree.map(lambda a: a[0], inputs_mb)
            inputs_mb = jax.tree.map(lambda a, d: a.astype(d), inputs_mb,
                                     in_dtypes)
            sk_protos = {k: localize(v)
                         for k, v in (skip_protos or {}).items()}
            outs, res = run_pipeline(stage_apply, params, inputs_mb, cfg_run,
                                     skips=skips, skip_protos=sk_protos,
                                     resident=resident,
                                     carry_proto=localize(carry_proto),
                                     axis=axis, rank=rank_arr[0])
            outs = jax.tree.map(lambda a: a[None], outs)
            res = jax.tree.map(lambda a: a[None], res)
            return outs, res

    def call(stage_params, inputs_mb, resident=None):
        resident = {} if resident is None else resident
        n, m = cfg.pipe, cfg.n_micro
        streaming = cfg.stream_inputs and n > 1 and m % n == 0
        cfg_run = cfg.with_(stream_inputs=streaming)
        in_dtypes = jax.tree.map(lambda a: a.dtype, inputs_mb)
        if streaming:
            k = m // n
            inputs_mb = jax.tree.map(
                lambda a: a.reshape((k, n) + a.shape[1:]).swapaxes(0, 1),
                inputs_mb)
            in_spec_x = P(axis)
            up = inputs_mb
        else:
            in_spec_x = P()
            up = jax.tree.map(
                lambda a: a.astype(jnp.float32)
                if a.dtype == jnp.bfloat16 else a, inputs_mb)
        rank_arr = jnp.arange(n, dtype=jnp.int32)
        if cfg.pipe > 1:
            axis_names = {axis}
            in_spec_res = out_spec_res = P(axis)
            out_spec_outs = P(axis)
            bdiv = 1
            if not compat.JAX_HAS_NEW_API:
                # jax 0.4.x: the partial-auto partitioner aborts on this
                # program shape (XLA IsManualSubgroup check), so go FULLY
                # manual and express what GSPMD would have derived by hand:
                # every non-pipe axis becomes batch parallelism.  The
                # tensor-parallel constraints inside the stage are already
                # elided (compat.skip_constraints), so treating ``tp`` as
                # extra DP is exact — each rank computes a distinct batch
                # slice and the shard_map transpose psums parameter
                # cotangents over the non-pipe axes (the DP grad reduction).
                axis_names = set(mesh.axis_names)
                baxes, nd = _oldjax_batch_axes(mesh, axis)
                bdim_in = 2 if streaming else 1
                if nd > 1:
                    def divisible(leaf, d):
                        return leaf.ndim > d and leaf.shape[d] % nd == 0
                    if not (all(divisible(l, bdim_in)
                                for l in jax.tree.leaves(up))
                            and all(l.ndim < 4 or divisible(l, 3)
                                    for l in jax.tree.leaves(resident))):
                        raise _oldjax_divisibility_error(nd)
                    bdiv = nd
                    if streaming:
                        in_spec_x = P(axis, None, baxes)
                    else:
                        in_spec_x = P(None, baxes)
                    # resident caches: [n, L, m, mb, ...] -> batch at dim 3;
                    # low-rank leaves (per-micro trackers) are replicated.
                    def res_spec(leaf):
                        if leaf.ndim >= 4:
                            return P(axis, None, None, baxes)
                        return P(axis)
                    in_spec_res = jax.tree.map(res_spec, resident)
                    out_spec_res = in_spec_res
                    out_spec_outs = P(axis, None, baxes)
            fn = shard_map(
                functools.partial(inner, in_dtypes=in_dtypes,
                                  cfg_run=cfg_run, bdiv=bdiv), mesh=mesh,
                in_specs=(P(axis), P(axis), in_spec_x, in_spec_res),
                out_specs=(out_spec_outs, out_spec_res),
                axis_names=axis_names, check_vma=False)
        else:
            # Degenerate single-stage pipeline: plain sequential execution,
            # no manual axis (avoids size-1 manual subgroups).
            fn = functools.partial(inner, in_dtypes=in_dtypes,
                                   cfg_run=cfg_run.with_(stream_inputs=False))
        return fn(rank_arr, stage_params, up, resident)

    return call


def last_stage_output(outputs):
    """Extract the last pipe rank's collected outputs: [m, ...] pytree."""
    return jax.tree.map(lambda a: a[-1], outputs)


def microbatch(tree, n_micro: int):
    """Split leading batch dim B -> [n_micro, B // n_micro, ...]."""
    def f(a):
        b = a.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} not divisible by n_micro {n_micro}")
        return a.reshape((n_micro, b // n_micro) + a.shape[1:])
    return jax.tree.map(f, tree)


def unmicrobatch(tree):
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), tree)
