"""GPipe micro-batch pipeline parallelism as a JAX transform (paper §2–3).

The pipeline runs inside a :func:`jax.shard_map` that is *manual* over the
``pipe`` mesh axis and *auto* (GSPMD) over every other axis (``pod``,
``data``, ``tp``): stage ``j``'s parameters live on pipe-rank ``j`` (the
leading axis of the stacked stage parameters is sharded over ``pipe``), while
FSDP/TP/DP sharding inside a stage is delegated to the compiler via
``with_sharding_constraint`` — the paper's "device j holds partition j"
placement, generalized to a 512-chip mesh.

The deterministic clock-cycle (paper Algorithm 1) is a loop over ticks
``t = 0 .. m+n-2``; at tick ``t``, pipe-rank ``j`` executes task
``F_{t-j, j}`` (ranks whose ``t - j`` falls outside ``[0, m)`` are in the
fill/drain bubble and compute on zeros; their results are masked out of the
collected outputs, so autodiff assigns them exactly zero cotangent and the
bubble contributes nothing to gradients).  Boundary activations move with a
single-step ``collective-permute`` ring shift; skip tensors move via portals
(:mod:`repro.core.skip`).  ``jax.grad`` through the loop yields the reverse
clock-cycle with rematerialization scheduled immediately before each stage
backward — the paper's fork/join + Checkpoint/Recompute pairing, obtained
structurally (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.core import checkpointing
from repro.core.skip import SkipSpec, portal_sends, ring_init, ring_push, ring_read

PIPE_AXIS = "pipe"


@dataclass
class TickCtx:
    """Per-tick context handed to the stage function."""
    stage: jax.Array          # axis_index('pipe') — traced
    micro: jax.Array          # clamped micro-batch index  t - stage
    valid: jax.Array          # bool: is (micro, stage) a real task this tick?
    t: Any                    # tick counter (traced in scan mode, int if unrolled)
    fresh: Any                # stage-0 input pytree slice for this tick
    n_stages: int
    n_micro: int


# StageApplyFn signature:
#   stage_apply(stage_params, carry, skips_in: dict, resident, ctx: TickCtx)
#       -> (carry_out, skips_out: dict, resident_out)
StageApplyFn = Callable[..., Tuple[Any, Dict[str, Any], Any]]


def _select(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _shift_chain(value, n: int, axis: str):
    """Main pipeline hop: rank j -> j+1 (rank 0 receives zeros)."""
    if n == 1:
        return jax.tree.map(jnp.zeros_like, value)
    perm = [(i, i + 1) for i in range(n - 1)]
    return jax.tree.map(lambda v: jax.lax.ppermute(v, axis, perm), value)


BATCH_AXES = ("pod", "data")


def _constrain_batch0(tree, *, lead: int = 0):
    """Constrain pytree leaves: batch dim = ``lead`` over (pod, data).

    GSPMD does not reliably propagate the data sharding of the mini-batch
    into the clock-loop carries (state, outputs, per-tick slices) that start
    from jnp.zeros — without these constraints every carry is replicated
    over the data axis and per-device memory blows up by |data|x.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or not set(BATCH_AXES) <= set(mesh.axis_names):
        return tree

    nshard = 1
    for ax in BATCH_AXES:
        nshard *= mesh.shape[ax]

    def one(a):
        if a.ndim <= lead or a.shape[lead] % nshard:
            return a
        spec = [None] * a.ndim
        spec[lead] = BATCH_AXES
        return jax.lax.with_sharding_constraint(a, P(*spec))
    return jax.tree.map(one, tree)


def _barrier(*trees):
    """Ablation hook (overlap=False): serialize comm against compute, the
    analogue of torchgpipe's default-stream (no copy-stream) baseline."""
    flat, tds = zip(*[jax.tree_util.tree_flatten(t) for t in trees])
    leaves = [l for f in flat for l in f]
    if not leaves:
        return trees
    out = jax.lax.optimization_barrier(tuple(leaves))
    res, k = [], 0
    for f, td in zip(flat, tds):
        res.append(jax.tree_util.tree_unflatten(td, out[k:k + len(f)]))
        k += len(f)
    return tuple(res)


# ---------------------------------------------------------------------------
# The clock-cycle loop (runs INSIDE shard_map, manual over 'pipe')
# ---------------------------------------------------------------------------

def run_pipeline(stage_apply: StageApplyFn,
                 stage_params,
                 inputs_mb,
                 cfg: ParallelConfig,
                 *,
                 skips: Sequence[SkipSpec] = (),
                 skip_protos: Optional[Dict[str, Any]] = None,
                 resident=None,
                 carry_proto=None,
                 axis: str = PIPE_AXIS):
    """Execute the GPipe schedule for one mini-batch.

    Args:
      stage_apply: per-stage function, see StageApplyFn.
      stage_params: this rank's stage parameters (already squeezed).
      inputs_mb: pytree with leading micro-batch axis [m, ...] (replicated
        over pipe; only rank 0 consumes it as ``ctx.fresh``).
      cfg: ParallelConfig (n_micro, pipe, remat, portals, overlap, ...).
      skips: skip edges (portal or threaded per cfg.portals).
      skip_protos: {name: pytree of ShapeDtypeStruct} for ring/slot init.
      resident: rank-local pytree (KV caches / SSM state), updated only on
        valid ticks.
      carry_proto: pytree of ShapeDtypeStruct describing the stage-boundary
        carry. Defaults to the structure of one fresh input slice.

    Returns: (outputs [m, ...carry], resident) — outputs valid on last rank.
    """
    n, m = cfg.pipe, cfg.n_micro
    T = m + n - 1
    # pipe == 1 runs outside shard_map (see pipeline_call): no axis to index.
    idx = jax.lax.axis_index(axis) if n > 1 else jnp.zeros((), jnp.int32)
    skip_protos = skip_protos or {}
    resident = {} if resident is None else resident

    def zeros_of(proto):
        return jax.tree.map(
            lambda p: jnp.zeros(tuple(p.shape), jnp.dtype(p.dtype)), proto)

    if carry_proto is None:
        carry0 = jax.tree.map(lambda a: jnp.zeros(a.shape[1:], a.dtype), inputs_mb)
    else:
        carry0 = zeros_of(carry_proto)
    outputs0 = jax.tree.map(lambda c: jnp.zeros((m,) + c.shape, c.dtype), carry0)

    if cfg.portals:
        comms0 = {s.name: ring_init(s, skip_protos[s.name]) for s in skips}
    else:
        comms0 = {s.name: zeros_of(skip_protos[s.name]) for s in skips}

    inputs_mb = _constrain_batch0(inputs_mb, lead=1)
    streaming = cfg.stream_inputs and n > 1
    k = m // n if streaming else 0   # micro-batches per rank (validated in
    #                                  pipeline_call: m % n == 0)

    def tick_body(state, comms, outputs, resident, t, stream_buf=None):
        state = _constrain_batch0(state)
        outputs = _constrain_batch0(outputs, lead=1)
        if streaming:
            # stream_buf slot s holds micro-batch s*n + ((t + rank) mod n):
            # after t one-hop rotations, rank 0's slot t//n is micro-batch t.
            fresh = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, jnp.clip(t // n, 0, k - 1), 0, keepdims=False),
                stream_buf)
        else:
            fresh = _constrain_batch0(jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, jnp.minimum(t, m - 1), 0, keepdims=False), inputs_mb))
        micro_raw = t - idx
        valid = jnp.logical_and(micro_raw >= 0, micro_raw < m)
        micro = jnp.clip(micro_raw, 0, m - 1)
        ctx = TickCtx(stage=idx, micro=micro, valid=valid, t=t, fresh=fresh,
                      n_stages=n, n_micro=m)

        # --- skip consumption --------------------------------------------
        skips_in = {}
        for s in skips:
            if cfg.portals:
                rd = None
                for dst in s.dsts:
                    v = ring_read(s, dst, comms[s.name][dst])
                    rd = v if rd is None else _select(idx == dst, v, rd)
                skips_in[s.name] = rd
            else:
                skips_in[s.name] = comms[s.name]

        # --- compute -------------------------------------------------------
        fn = checkpointing.wrap_stage(
            lambda p, c, si, r: stage_apply(p, c, si, r, ctx), cfg.remat)
        carry_out, skips_out, resident_new = fn(stage_params, state, skips_in,
                                                resident)
        # bubble ticks must not mutate resident state (KV caches etc.)
        resident = _select(valid, resident_new, resident)

        # --- sends -----------------------------------------------------------
        if not cfg.overlap:
            (carry_out,), = (_barrier(carry_out),)
        carry_out = _constrain_batch0(carry_out)
        state_next = _shift_chain(carry_out, n, axis)
        comms_next = {}
        for s in skips:
            v = skips_out[s.name]
            if cfg.portals:
                recvs = portal_sends(s, v, axis)
                comms_next[s.name] = {
                    dst: ring_push(comms[s.name][dst], recvs[dst])
                    for dst in s.dsts}
            else:
                # threaded: slot travels with the micro-batch, hop by hop
                slot = _select(idx == s.src_stage, v, skips_in[s.name])
                comms_next[s.name] = _shift_chain(slot, n, axis)

        # --- output collection at the last stage --------------------------
        slot_i = jnp.clip(t - (n - 1), 0, m - 1)
        take = jnp.logical_and(idx == n - 1, t >= n - 1)

        def upd(buf, y):
            cur = jax.lax.dynamic_index_in_dim(buf, slot_i, 0, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.where(take, y, cur), slot_i, 0)

        outputs = jax.tree.map(upd, outputs, carry_out)

        if streaming:
            # rotate the input stream one rank towards stage 0 (full ring).
            rot = [(i, (i - 1) % n) for i in range(n)]
            stream_buf = jax.tree.map(
                lambda a: jax.lax.ppermute(a, axis, rot), stream_buf)
            return state_next, comms_next, outputs, resident, stream_buf
        return state_next, comms_next, outputs, resident

    stream0 = inputs_mb if streaming else None

    if cfg.unroll_ticks:
        state, comms, outputs, stream = carry0, comms0, outputs0, stream0
        for t in range(T):
            out = tick_body(state, comms, outputs, resident,
                            jnp.asarray(t), stream)
            if streaming:
                state, comms, outputs, resident, stream = out
            else:
                state, comms, outputs, resident = out
    else:
        def scan_body(loop, t):
            if streaming:
                state, comms, outputs, resident, stream = loop
                return tick_body(state, comms, outputs, resident, t,
                                 stream), None
            state, comms, outputs, resident = loop
            return tick_body(state, comms, outputs, resident, t), None
        init = ((carry0, comms0, outputs0, resident, stream0) if streaming
                else (carry0, comms0, outputs0, resident))
        final, _ = jax.lax.scan(scan_body, init, jnp.arange(T))
        outputs, resident = final[2], final[3]

    return outputs, resident


# ---------------------------------------------------------------------------
# shard_map wrapper: the public entry point
# ---------------------------------------------------------------------------

def pipeline_call(stage_apply: StageApplyFn,
                  *,
                  mesh: Mesh,
                  cfg: ParallelConfig,
                  skips: Sequence[SkipSpec] = (),
                  skip_protos: Optional[Dict[str, Any]] = None,
                  carry_proto=None,
                  axis: str = PIPE_AXIS):
    """Build ``(stage_params, inputs_mb, resident) -> (outputs, resident)``.

    ``stage_params``/``resident`` leaves carry a leading ``n_stages`` axis
    sharded over ``pipe``; ``inputs_mb`` is replicated over ``pipe`` (its
    batch-ish dims may be sharded over the auto axes).  ``outputs`` gains a
    leading ``pipe``-sharded axis: index ``[-1]`` for the last stage's
    results (:func:`last_stage_output`).
    """
    # Input modes across the shard_map boundary:
    #  * replicated (default): the transpose of the pipe-replicated in_spec
    #    is a psum over the *manual* axis — this both dominates collective
    #    bytes for embedding-fed models AND crashes XLA-CPU's
    #    AllReducePromotion in bf16, so the inputs cross in fp32.
    #  * streaming (cfg.stream_inputs, m % n == 0): micro-batches are
    #    SHARDED over pipe (micro-batch i at rank i%n, slot i//n) and
    #    rotated one hop per tick; the transpose is a reverse rotation (no
    #    psum), memory drops by n, and bf16 is safe.
    def inner(params, inputs_mb, resident, in_dtypes, cfg_run):
        params = jax.tree.map(lambda a: a[0], params)
        resident = jax.tree.map(lambda a: a[0], resident)
        if cfg_run.stream_inputs:
            inputs_mb = jax.tree.map(lambda a: a[0], inputs_mb)
        inputs_mb = jax.tree.map(lambda a, d: a.astype(d), inputs_mb,
                                 in_dtypes)
        outs, res = run_pipeline(stage_apply, params, inputs_mb, cfg_run,
                                 skips=skips, skip_protos=skip_protos,
                                 resident=resident, carry_proto=carry_proto,
                                 axis=axis)
        outs = jax.tree.map(lambda a: a[None], outs)
        res = jax.tree.map(lambda a: a[None], res)
        return outs, res

    def call(stage_params, inputs_mb, resident=None):
        resident = {} if resident is None else resident
        n, m = cfg.pipe, cfg.n_micro
        streaming = cfg.stream_inputs and n > 1 and m % n == 0
        cfg_run = cfg.with_(stream_inputs=streaming)
        in_dtypes = jax.tree.map(lambda a: a.dtype, inputs_mb)
        if streaming:
            k = m // n
            inputs_mb = jax.tree.map(
                lambda a: a.reshape((k, n) + a.shape[1:]).swapaxes(0, 1),
                inputs_mb)
            in_spec_x = P(axis)
            up = inputs_mb
        else:
            in_spec_x = P()
            up = jax.tree.map(
                lambda a: a.astype(jnp.float32)
                if a.dtype == jnp.bfloat16 else a, inputs_mb)
        if cfg.pipe > 1:
            fn = shard_map(
                functools.partial(inner, in_dtypes=in_dtypes,
                                  cfg_run=cfg_run), mesh=mesh,
                in_specs=(P(axis), in_spec_x, P(axis)),
                out_specs=(P(axis), P(axis)),
                axis_names={axis}, check_vma=False)
        else:
            # Degenerate single-stage pipeline: plain sequential execution,
            # no manual axis (avoids size-1 manual subgroups).
            fn = functools.partial(inner, in_dtypes=in_dtypes,
                                   cfg_run=cfg_run.with_(stream_inputs=False))
        return fn(stage_params, up, resident)

    return call


def last_stage_output(outputs):
    """Extract the last pipe rank's collected outputs: [m, ...] pytree."""
    return jax.tree.map(lambda a: a[-1], outputs)


def microbatch(tree, n_micro: int):
    """Split leading batch dim B -> [n_micro, B // n_micro, ...]."""
    def f(a):
        b = a.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} not divisible by n_micro {n_micro}")
        return a.reshape((n_micro, b // n_micro) + a.shape[1:])
    return jax.tree.map(f, tree)


def unmicrobatch(tree):
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), tree)
