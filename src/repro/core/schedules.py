"""Pipeline schedules as host-level task tables.

The paper's *deterministic clock-cycle* (Algorithm 1) totally orders the tasks
``F_{i,j}`` by their distance ``k = i + j`` to ``F_{0,0}`` (0-indexed here; the
paper uses 1-indexing so its ``k = i + j - 1``).  In an eager framework that
ordering is what the host thread must issue; in our trace-and-compile setting
the same ordering is realized *structurally* by a scan over clock ticks — this
module is the single source of truth both for that scan (which tick runs which
task) and for the property tests that prove the orderings agree with the
paper's Algorithm 1 and its dependency constraints (§2.1).

Task naming follows the paper: F(i, j) is the forward of micro-batch ``i`` on
partition ``j``; B(i, j) its backward; R(i, j) the recomputation ``F'_{i,j}``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple


@dataclass(frozen=True, order=True)
class Task:
    kind: str        # "F" | "B" | "R"
    micro: int       # i  (0-indexed)
    stage: int       # j  (0-indexed)

    def __repr__(self) -> str:  # compact: F[i,j]
        return f"{self.kind}[{self.micro},{self.stage}]"


def clock_cycles(m: int, n: int) -> Iterator[List[Task]]:
    """Paper Algorithm 1 (deterministic clock-cycle), 0-indexed.

    Yields, for each clock tick ``k = 0 .. m+n-2``, the list of forward tasks
    ``F_{i,j}`` with ``i + j == k``.  Tasks within one tick are independent
    (they touch different stages *and* different micro-batches) and may be
    issued concurrently, exactly as in the paper.
    """
    if m < 1 or n < 1:
        raise ValueError(f"need m >= 1 and n >= 1, got {m=} {n=}")
    for k in range(m + n - 1):
        yield [Task("F", i, k - i)
               for i in range(max(0, k - n + 1), min(m, k + 1))]


def gpipe_backward_cycles(m: int, n: int, *, checkpoint: bool = True,
                          recompute_last_micro: bool = False) -> Iterator[List[Task]]:
    """The reverse clock-cycle that autodiff induces for GPipe.

    Backward task ``B_{i,j}`` runs at reverse tick ``k' = (m-1-i) + (n-1-j)``.
    With checkpointing, the recomputation ``R_{i,j}`` is scheduled in the same
    tick immediately before ``B_{i,j}`` — except for each stage's *last*
    forward micro-batch (``i == m-1``), whose recompute the paper elides
    (§2.1: "re-computations for the last micro-batch are unnecessary").
    """
    for k in range(m + n - 1):
        tasks: List[Task] = []
        for i in range(m):
            j = (m - 1 - i) + (n - 1) - k
            if 0 <= j < n:
                if checkpoint and (recompute_last_micro or i != m - 1):
                    tasks.append(Task("R", i, j))
                tasks.append(Task("B", i, j))
        yield tasks


def gpipe_schedule(m: int, n: int, *, checkpoint: bool = True,
                   recompute_last_micro: bool = False) -> List[List[Task]]:
    """Full GPipe schedule: forward fill-drain, then backward fill-drain."""
    fwd = list(clock_cycles(m, n))
    bwd = list(gpipe_backward_cycles(m, n, checkpoint=checkpoint,
                                     recompute_last_micro=recompute_last_micro))
    return fwd + bwd


def one_f_one_b_schedule(m: int, n: int) -> List[List[Task]]:
    """1F1B (PipeDream-flush) schedule — beyond-paper optimization.

    Same synchronous semantics as GPipe (flush every mini-batch) but each
    stage starts draining backward as soon as its first backward dependency
    resolves, bounding stashed activations by ``n - j`` instead of ``m``.

    Built per-stage: stage ``j`` runs ``min(n - j, m)`` warmup forwards, then
    alternates 1F/1B, then drains remaining backwards.  The global table is
    produced by simulating the per-stage queues under the cross-stage
    dependencies (F(i,j) needs F(i,j-1); B(i,j) needs B(i,j+1)).
    """
    per_stage: List[List[Task]] = []
    for j in range(n):
        warm = min(n - j, m)
        order: List[Task] = [Task("F", i, j) for i in range(warm)]
        fi, bi = warm, 0
        while bi < m:
            order.append(Task("B", bi, j)); bi += 1
            if fi < m:
                order.append(Task("F", fi, j)); fi += 1
        per_stage.append(order)

    done = set()
    ptr = [0] * n
    table: List[List[Task]] = []
    while any(ptr[j] < len(per_stage[j]) for j in range(n)):
        tick: List[Task] = []
        for j in range(n):
            if ptr[j] >= len(per_stage[j]):
                continue
            t = per_stage[j][ptr[j]]
            dep_ok = (
                (t.kind == "F" and (t.stage == 0 or Task("F", t.micro, t.stage - 1) in done))
                or (t.kind == "B" and (t.stage == n - 1 or Task("B", t.micro, t.stage + 1) in done))
            )
            if dep_ok:
                tick.append(t)
        if not tick:
            raise RuntimeError(f"1F1B deadlock at ptrs={ptr} (m={m}, n={n})")
        for t in tick:
            done.add(t)
            ptr[t.stage] += 1
        table.append(tick)
    return table


# ---------------------------------------------------------------------------
# Schedule metrics (used by tests and by the balance/bubble reporting)
# ---------------------------------------------------------------------------

def bubble_fraction(m: int, n: int) -> float:
    """GPipe bubble fraction (n-1)/(m+n-1) — idle tick share per stage."""
    return (n - 1) / (m + n - 1)


def peak_stash(table: Sequence[Sequence[Task]], n: int, m: int) -> List[int]:
    """Peak number of outstanding forward activations stashed per stage."""
    live = [0] * n
    peak = [0] * n
    for tick in table:
        for t in tick:
            if t.kind == "F":
                live[t.stage] += 1
                peak[t.stage] = max(peak[t.stage], live[t.stage])
            elif t.kind == "B":
                live[t.stage] -= 1
    return peak


def validate(table: Sequence[Sequence[Task]], m: int, n: int,
             *, checkpoint: bool = False,
             recompute_last_micro: bool = False,
             backward_micro_order: bool = True,
             forward_only: bool = False) -> None:
    """Assert the schedule respects every dependency in the paper's §2 graph.

    Raises AssertionError on: missing/duplicate tasks, F(i,j) before
    F(i,j-1), B(i,j) before B(i,j+1), per-stage micro-batch order violations
    (F(i+1,j) before F(i,j) / B(i-1,j) before B(i,j), the dashed arrows of
    Fig. 2), or a B(i,j) without its R(i,j) earlier in the same stage.

    ``backward_micro_order=False`` relaxes the B-side dashed-arrow order:
    1F1B deliberately drains early backwards (B[i] before B[i+1] at a
    stage), which is a *schedule choice* in GPipe, not a data dependency.

    ``forward_only=True`` validates an inference / autodiff-backward plan:
    the table must cover every F task and contain no B at all (the reverse
    clock-cycle is induced outside the table).
    """
    seen = {}
    order = 0
    for tick in table:
        stages_this_tick = set()
        for t in tick:
            assert t not in seen, f"duplicate {t}"
            assert (t.stage, t.kind) not in stages_this_tick, \
                f"stage {t.stage} runs two {t.kind} tasks in one tick"
            stages_this_tick.add((t.stage, t.kind))
            seen[t] = order
        order += 1
    expect_f = {Task("F", i, j) for i in range(m) for j in range(n)}
    expect_b = {Task("B", i, j) for i in range(m) for j in range(n)}
    have = set(seen)
    assert expect_f <= have, f"missing forwards: {sorted(expect_f - have)[:4]}"
    if forward_only:
        assert not any(t.kind == "B" for t in have), \
            "forward-only table contains backward tasks"
    else:
        assert expect_b <= have, \
            f"missing backwards: {sorted(expect_b - have)[:4]}"
    for i in range(m):
        for j in range(n):
            if j > 0:
                assert seen[Task("F", i, j - 1)] < seen[Task("F", i, j)]
                if not forward_only:
                    assert seen[Task("B", i, j)] < seen[Task("B", i, j - 1)]
            if i > 0:
                assert seen[Task("F", i - 1, j)] < seen[Task("F", i, j)], \
                    f"micro-batch order: F[{i-1},{j}] !< F[{i},{j}]"
                if backward_micro_order and not forward_only:
                    assert seen[Task("B", i, j)] < seen[Task("B", i - 1, j)], \
                        f"micro-batch order: B[{i},{j}] !< B[{i-1},{j}]"
            if checkpoint:
                needs_r = recompute_last_micro or i != m - 1
                if needs_r:
                    r = Task("R", i, j)
                    assert r in seen and seen[r] <= seen[Task("B", i, j)], \
                        f"{r} must precede B[{i},{j}]"
