"""Pipeline schedules as host-level task tables.

The paper's *deterministic clock-cycle* (Algorithm 1) totally orders the tasks
``F_{i,j}`` by their distance ``k = i + j`` to ``F_{0,0}`` (0-indexed here; the
paper uses 1-indexing so its ``k = i + j - 1``).  In an eager framework that
ordering is what the host thread must issue; in our trace-and-compile setting
the same ordering is realized *structurally* by a scan over clock ticks — this
module is the single source of truth both for that scan (which tick runs which
task) and for the property tests that prove the orderings agree with the
paper's Algorithm 1 and its dependency constraints (§2.1).

Task naming follows the paper: F(i, j) is the forward of micro-batch ``i`` on
partition ``j``; B(i, j) its backward; R(i, j) the recomputation ``F'_{i,j}``.

Beyond-paper schedules extend the same vocabulary:

* **interleaved 1F1B** (Megatron-style virtual stages, Narayanan et al.):
  the model is cut into ``n * v`` stages and rank ``r`` hosts the *chunks*
  ``{r, r + n, ..., r + (v-1) n}``.  ``Task.stage`` is always the GLOBAL
  stage index; the executing rank is ``stage % n``.  Finer stages shrink
  the fill/drain bubble by ~``1/v`` at the cost of ``v``× more boundary
  hops.

* **zero-bubble split backward** (ZB-H1 flavour, arXiv 2405.18047 /
  2401.10241): ``B`` is decomposed into ``Bx`` (input cotangent — the only
  part on the inter-stage critical path) and ``Bw`` (weight gradient),
  and the ``Bw`` tasks are drained into ticks where a rank would otherwise
  idle.  ``Bx`` inherits B's dependency chain; ``Bw(i,j)`` only requires
  ``Bx(i,j)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

#: kinds that chain backwards across stages (B(i,j) needs <kind>(i,j+1))
_BWD_CHAIN = ("B", "Bx")


@dataclass(frozen=True, order=True)
class Task:
    kind: str        # "F" | "B" | "R" | "Bx" | "Bw"
    micro: int       # i  (0-indexed)
    stage: int       # j  (0-indexed, GLOBAL stage — rank is stage % n_ranks)

    def __repr__(self) -> str:  # compact: F[i,j]
        return f"{self.kind}[{self.micro},{self.stage}]"


def clock_cycles(m: int, n: int) -> Iterator[List[Task]]:
    """Paper Algorithm 1 (deterministic clock-cycle), 0-indexed.

    Yields, for each clock tick ``k = 0 .. m+n-2``, the list of forward tasks
    ``F_{i,j}`` with ``i + j == k``.  Tasks within one tick are independent
    (they touch different stages *and* different micro-batches) and may be
    issued concurrently, exactly as in the paper.
    """
    if m < 1 or n < 1:
        raise ValueError(f"need m >= 1 and n >= 1, got {m=} {n=}")
    for k in range(m + n - 1):
        yield [Task("F", i, k - i)
               for i in range(max(0, k - n + 1), min(m, k + 1))]


def gpipe_backward_cycles(m: int, n: int, *, checkpoint: bool = True,
                          recompute_last_micro: bool = False) -> Iterator[List[Task]]:
    """The reverse clock-cycle that autodiff induces for GPipe.

    Backward task ``B_{i,j}`` runs at reverse tick ``k' = (m-1-i) + (n-1-j)``.
    With checkpointing, the recomputation ``R_{i,j}`` is scheduled in the same
    tick immediately before ``B_{i,j}`` — except for each stage's *last*
    forward micro-batch (``i == m-1``), whose recompute the paper elides
    (§2.1: "re-computations for the last micro-batch are unnecessary").
    """
    for k in range(m + n - 1):
        tasks: List[Task] = []
        for i in range(m):
            j = (m - 1 - i) + (n - 1) - k
            if 0 <= j < n:
                if checkpoint and (recompute_last_micro or i != m - 1):
                    tasks.append(Task("R", i, j))
                tasks.append(Task("B", i, j))
        yield tasks


def gpipe_schedule(m: int, n: int, *, checkpoint: bool = True,
                   recompute_last_micro: bool = False) -> List[List[Task]]:
    """Full GPipe schedule: forward fill-drain, then backward fill-drain."""
    fwd = list(clock_cycles(m, n))
    bwd = list(gpipe_backward_cycles(m, n, checkpoint=checkpoint,
                                     recompute_last_micro=recompute_last_micro))
    return fwd + bwd


# ---------------------------------------------------------------------------
# Dependency-driven packing (shared by 1F1B / interleaved / zero-bubble)
# ---------------------------------------------------------------------------

def _pack(per_rank: Sequence[Sequence[Task]], ranks: int, n_stages: int,
          *, fill_bw: bool = False) -> List[List[Task]]:
    """Greedily pack fixed per-rank task orders into the earliest ticks that
    satisfy the cross-stage dependencies (F(i,s) after F(i,s-1); a backward-
    chain task after its successor stage's; the last stage's backward after
    its own forward).

    With ``fill_bw`` every executed ``Bx(i,s)`` enqueues ``Bw(i,s)`` on the
    owning rank; a rank whose next main-queue task is not yet runnable (or
    whose queue is drained) runs its oldest pending ``Bw`` instead — the
    ZB-H1 bubble-filling rule.  ``Bw`` has no cross-rank dependencies, so
    the fill can never deadlock.
    """
    done = {}
    ptr = [0] * ranks
    pending_w: List[List[Task]] = [[] for _ in range(ranks)]
    table: List[List[Task]] = []
    t = 0

    def runnable(task: Task) -> bool:
        if task.kind == "F":
            return task.stage == 0 or Task("F", task.micro, task.stage - 1) in done
        assert task.kind in _BWD_CHAIN
        if task.stage == n_stages - 1:
            return Task("F", task.micro, task.stage) in done
        return any(Task(k, task.micro, task.stage + 1) in done
                   for k in _BWD_CHAIN)

    while any(ptr[r] < len(per_rank[r]) for r in range(ranks)) \
            or any(pending_w):
        tick: List[Task] = []
        for r in range(ranks):
            task: Optional[Task] = None
            if ptr[r] < len(per_rank[r]) and runnable(per_rank[r][ptr[r]]):
                task = per_rank[r][ptr[r]]
                ptr[r] += 1
            elif pending_w[r]:
                task = pending_w[r].pop(0)
            if task is not None:
                tick.append(task)
        if not tick:
            raise RuntimeError(f"schedule deadlock at tick {t}, ptrs={ptr}")
        for task in tick:
            done[task] = t
            if fill_bw and task.kind == "Bx":
                pending_w[task.stage % ranks].append(
                    Task("Bw", task.micro, task.stage))
        table.append(tick)
        t += 1
    return table


def one_f_one_b_schedule(m: int, n: int) -> List[List[Task]]:
    """1F1B (PipeDream-flush) schedule — beyond-paper optimization.

    Same synchronous semantics as GPipe (flush every mini-batch) but each
    stage starts draining backward as soon as its first backward dependency
    resolves, bounding stashed activations by ``n - j`` instead of ``m``.

    Built per-stage: stage ``j`` runs ``min(n - j, m)`` warmup forwards, then
    alternates 1F/1B, then drains remaining backwards.  The global table is
    produced by packing the per-stage queues under the cross-stage
    dependencies (F(i,j) needs F(i,j-1); B(i,j) needs B(i,j+1)).
    """
    per_rank = [_one_f_one_b_order(m, n, j, bwd_kind="B") for j in range(n)]
    return _pack(per_rank, n, n)


def _one_f_one_b_order(m: int, n: int, j: int, *, bwd_kind: str) -> List[Task]:
    """Stage ``j``'s 1F1B issue order: warmup forwards, steady 1F/1B, drain."""
    warm = min(n - j, m)
    order: List[Task] = [Task("F", i, j) for i in range(warm)]
    fi, bi = warm, 0
    while bi < m:
        order.append(Task(bwd_kind, bi, j)); bi += 1
        if fi < m:
            order.append(Task("F", fi, j)); fi += 1
    return order


def interleaved_1f1b_schedule(m: int, n: int, v: int) -> List[List[Task]]:
    """Interleaved 1F1B with ``v`` virtual stages (chunks) per rank.

    Megatron-style (Narayanan et al., PAPERS.md): global stage
    ``s = c * n + r`` runs on rank ``r = s % n``; micro-batches advance in
    waves of ``n``, cycling through the chunks, so the fill bubble shrinks
    from ``(n-1)`` full-stage slots to ``(n-1)`` chunk slots (≈ ``1/v``).
    Requires ``m % n == 0`` (the wave width), per Megatron.
    """
    if v < 1:
        raise ValueError(f"need v >= 1, got {v=}")
    if v == 1:
        return one_f_one_b_schedule(m, n)
    if m % n:
        raise ValueError(
            f"interleaved schedule needs n_micro ({m}) divisible by "
            f"pipe ({n})")

    def unit(r: int, k: int, *, back: bool) -> Task:
        c = (k // n) % v
        if back:
            c = v - 1 - c
        i = (k // (n * v)) * n + (k % n)
        return Task("B" if back else "F", i, c * n + r)

    total = m * v
    per_rank: List[List[Task]] = []
    for r in range(n):
        warm = min((n - r - 1) * 2 + (v - 1) * n, total)
        order = [unit(r, k, back=False) for k in range(warm)]
        fi, bi = warm, 0
        while bi < total:
            if fi < total:
                order.append(unit(r, fi, back=False)); fi += 1
            order.append(unit(r, bi, back=True)); bi += 1
        per_rank.append(order)
    return _pack(per_rank, n, n * v)


def zb_schedule(m: int, n: int) -> List[List[Task]]:
    """ZB-H1-style split-backward schedule (arXiv 2405.18047).

    1F1B's issue order with ``B`` replaced by ``Bx`` (input cotangent — the
    only backward half other stages wait for), while the decoupled weight
    gradients ``Bw`` fill ticks where a rank's main queue is blocked and the
    drain tail.  Same flush semantics and activation bound as 1F1B; the
    bubble fraction drops because former idle slots now do useful work.
    """
    per_rank = [_one_f_one_b_order(m, n, j, bwd_kind="Bx") for j in range(n)]
    return _pack(per_rank, n, n, fill_bw=True)


# ---------------------------------------------------------------------------
# Schedule metrics (used by tests and by the balance/bubble reporting)
# ---------------------------------------------------------------------------

def bubble_fraction(table: Sequence[Sequence[Task]], *,
                    ranks: Optional[int] = None) -> float:
    """Idle share of the table: idle (rank, tick) slots / total slots.

    Computed from the task table itself, so it is correct for every
    schedule shape — GPipe's fill/drain gives the paper's closed form
    ``(n-1)/(m+n-1)``, 1F1B the same, interleaved ≈ ``(n-1)/v`` chunk
    slots, and split-backward tables get credit for the ``Bw``-filled
    ticks.  ``ranks`` defaults to the number of distinct executing ranks
    (``stage % ranks``) inferred as ``max stage + 1``; pass it explicitly
    for chunked tables.  R (recompute) tasks ride along with their B and
    are not counted as separate busy slots.
    """
    if not table:
        return 0.0
    if ranks is None:
        ranks = max(t.stage for tick in table for t in tick) + 1
    T = len(table)
    busy = sum(1 for tick in table for t in tick if t.kind != "R")
    return 1.0 - busy / (T * ranks)


def ideal_bubble_fraction(m: int, n: int) -> float:
    """The paper's closed form for the GPipe clock: (n-1)/(m+n-1)."""
    return (n - 1) / (m + n - 1)


def peak_stash(table: Sequence[Sequence[Task]], n: int,
               *, ranks: Optional[int] = None) -> List[int]:
    """Peak number of outstanding forward activations stashed per stage.

    An activation goes live at its F and is freed by the LAST backward
    reader: ``B`` for fused tables, ``Bw`` for split-backward tables (the
    weight gradient still needs the stage input after ``Bx`` ran).  With
    ``ranks`` given, stages co-resident on one rank (interleaved chunks)
    are aggregated into per-RANK peaks — the footprint a device allocator
    actually charges.
    """
    has_bw = any(t.kind == "Bw" for tick in table for t in tick)
    free_kind = "Bw" if has_bw else "B"
    slots = ranks if ranks is not None else n
    live = [0] * slots
    peak = [0] * slots
    for tick in table:
        for t in tick:
            r = t.stage % slots
            if t.kind == "F":
                live[r] += 1
                peak[r] = max(peak[r], live[r])
            elif t.kind == free_kind:
                live[r] -= 1
    return peak


def _tick_index(table: Sequence[Sequence[Task]]):
    """Tick of each task, split by family: (F, B-or-Bx, Bw) dicts keyed
    ``(micro, stage)``."""
    f: dict = {}
    b: dict = {}
    w: dict = {}
    for t, tick in enumerate(table):
        for task in tick:
            if task.kind == "F":
                f[(task.micro, task.stage)] = t
            elif task.kind in ("B", "Bx"):
                b[(task.micro, task.stage)] = t
            elif task.kind == "Bw":
                w[(task.micro, task.stage)] = t
    return f, b, w


def _max_overlap(intervals: Sequence[Tuple[int, int]]) -> int:
    """Peak number of concurrently live CLOSED intervals [a, c].

    This is exactly the high-water mark of plan.py's free-list slot
    allocator (``_alloc_intervals``): a slot is reusable strictly after its
    last-use tick, so the allocator's peak equals the maximum overlap of
    the closed intervals — the interval-graph clique number.
    """
    if not intervals:
        return 0
    events = sorted([(a, 1) for a, _ in intervals]
                    + [(c + 1, -1) for _, c in intervals])
    live = peak = 0
    for _, d in events:
        live += d
        peak = max(peak, live)
    return peak


def peak_park(table: Sequence[Sequence[Task]], n: int,
              *, ranks: Optional[int] = None) -> List[int]:
    """EXACT per-rank high-water of the donated park buffer plan.py
    allocates: one interval per (micro, stage >= 1) boundary value, live
    from its ring arrival (producer's F + 1) until its last backward reader
    (``Bw`` for split tables, ``B`` otherwise; the consuming F for
    forward-only tables).  Unlike :func:`peak_stash` (the schedule-level
    activation bound), this predicts ``TaskPlan.per_stage_park`` slot for
    slot — stage 0 parks nothing, and the one-tick in-flight arrival is
    included."""
    slots = ranks if ranks is not None else n
    f, b, w = _tick_index(table)
    per_rank: List[List[Tuple[int, int]]] = [[] for _ in range(slots)]
    for (i, s), tf in f.items():
        if s == 0:
            continue
        arrive = f[(i, s - 1)] + 1
        last = w.get((i, s), b.get((i, s), tf))
        per_rank[s % slots].append((arrive, last))
    return [_max_overlap(iv) for iv in per_rank]


def peak_residuals(table: Sequence[Sequence[Task]], n: int,
                   *, ranks: Optional[int] = None) -> List[int]:
    """EXACT per-rank high-water of the residual stash a ``reuse`` plan
    allocates: one interval per (micro, stage), live from the Bx tick that
    materializes the vjp residuals until the Bw tick that consumes them.
    All zeros for fused-backward tables (nothing crosses ticks)."""
    slots = ranks if ranks is not None else n
    _, b, w = _tick_index(table)
    per_rank: List[List[Tuple[int, int]]] = [[] for _ in range(slots)]
    for (i, s), tw in w.items():
        tb = b.get((i, s))
        if tb is None:
            raise ValueError(f"Bw[{i},{s}] has no matching Bx")
        per_rank[s % slots].append((tb, tw))
    return [_max_overlap(iv) for iv in per_rank]


def default_task_cost(n_stages: int, ranks: Optional[int] = None,
                      *, residuals: str = "recompute", remat: str = "dots"):
    """Per-task cost model of the FUSED EXECUTOR, in stage-forward units.

    A stage holds ``ranks / n_stages`` of the model, so interleaved chunks
    cost proportionally less per task.  Backward flavours reflect what the
    executor actually runs (remat recompute included): fused ``B`` =
    recompute + input-grad + weight-grad = 3 forwards' work; split ``Bx`` /
    ``Bw`` = recompute + one gradient half = 2 each (the split pays one
    extra recompute per micro — ZB's remat tradeoff, visible here rather
    than hidden).  With ``residuals="reuse"`` the Bw re-reads the residuals
    its Bx stashed instead of rematerializing, so ``Bw`` drops to 1 (the
    weight-grad half alone) and the split's total cost returns to the fused
    ``B``'s 3 — true ZB-H1 pricing.  EXCEPT under ``remat="full"``: the
    full policy saves only the stage boundary inputs, so there is nothing
    to stash and the executor's Bw still rematerializes (the degenerate
    crossing the README policy table documents) — priced at 2 so the cost
    model never promises a payoff the executor cannot deliver.
    """
    ranks = n_stages if ranks is None else ranks
    share = ranks / n_stages          # fraction of the model per stage
    return weighted_task_cost([share] * n_stages,
                              residuals=residuals, remat=remat)


def weighted_task_cost(stage_weights: Sequence[float],
                       *, residuals: str = "recompute", remat: str = "dots"):
    """Per-task cost model with NON-UNIFORM stage weights.

    ``stage_weights[s]`` is stage ``s``'s forward cost in stage-forward
    units — for a balanced partition, ``stage_flops_s / total_flops *
    ranks`` so uniform stages reduce to :func:`default_task_cost`'s
    ``ranks / n_stages`` share.  Backward flavours use the same
    multipliers as :func:`default_task_cost` (B=3, Bx=2, Bw=1|2 per the
    residuals/remat pricing documented there).
    """
    weights = [float(w) for w in stage_weights]
    bw = 1.0 if residuals == "reuse" and remat != "full" else 2.0
    per_kind = {"F": 1.0, "B": 3.0, "Bx": 2.0, "Bw": bw, "R": 0.0}

    def cost(task: Task) -> float:
        return per_kind[task.kind] * weights[task.stage]
    return cost


def simulate_device_times(table: Sequence[Sequence[Task]], ranks: int,
                          cost_of=None, *, comm_cost: float = 0.0,
                          overlap_comm: bool = False,
                          bwd_comm_cost: Optional[float] = None,
                          route_edges: Sequence[Tuple[int, int]] = (),
                          route_comm_cost: Optional[float] = None,
                          overlap_routes: Optional[bool] = None
                          ) -> Tuple[float, List[float]]:
    """Event-driven critical path of a table on ``ranks`` DEDICATED devices.

    Each rank executes its tasks in table order; a task starts when its
    rank is free AND its cross-stage dependencies (F chain, backward
    chain, Bw-after-Bx, skip-route arrivals) have finished.  Returns
    ``(t_end, per_rank_busy)``; the pipeline bubble a device group
    actually pays is ``1 - sum(busy) / (ranks * t_end)``.

    ``comm_cost`` prices one cross-RANK boundary hop (chain ``ppermute``)
    in the same stage-forward units as ``cost_of`` (0 = the legacy
    zero-latency clock; co-resident interleaved chunks hop for free).
    ``bwd_comm_cost`` prices the cotangent chain hop separately (``None``
    = ``comm_cost``) — with a wire codec the two payload classes can ship
    at different precisions, so their byte-derived costs differ.
    ``overlap_comm`` selects the executor's comm story:

    * ``False`` (SPMD reference): the send is issued at the end of the
      producing task on the compute stream — the producer's rank is
      BLOCKED for the hop cost after the task, and the consumer sees
      ``finish + hop``.
    * ``True`` (MPMD double buffering): the send is latched and shipped
      one tick ahead, overlapping the producer's next compute — the
      consumer still sees ``finish + hop``, but the producer's rank
      is free immediately.  Pointwise no later than the serialized story,
      so the mpmd model is <= the spmd model for every table.

    ``route_edges`` lists skip/portal ``(src_stage, dst_stage)`` edges:
    ``F(i, dst)`` additionally waits on ``F(i, src)`` plus
    ``route_comm_cost`` (``None`` = ``comm_cost``) when the edge crosses
    ranks, and the mirrored cotangent makes the producer's backward wait
    on the consumer's.  ``overlap_routes`` (``None`` = follow
    ``overlap_comm``) decides whether route sends stall the producing
    rank (eager, serialized after the producer) or ship latched one tick
    ahead like the chain carry — the PR 7 route double buffering.

    This is the schedule-comparison clock for the speed tables: a
    single-host CPU bench timeshares every "device" over the same cores,
    so measured wall-clock reflects TOTAL work, not the critical path the
    schedule shortens (benchmarks/util.py documents the same convention
    for the paper-table model).
    """
    n_stages = max((t.stage for tick in table for t in tick), default=0) + 1
    if cost_of is None:
        cost_of = default_task_cost(n_stages, ranks)
    bwd_comm_cost = comm_cost if bwd_comm_cost is None else bwd_comm_cost
    route_comm_cost = comm_cost if route_comm_cost is None \
        else route_comm_cost
    overlap_routes = overlap_comm if overlap_routes is None \
        else overlap_routes
    route_edges = tuple((int(a), int(b)) for a, b in route_edges)
    split = any(t.kind == "Bx" for tick in table for t in tick)
    bk = "Bx" if split else "B"
    finish: dict = {}
    rank_free = [0.0] * ranks
    busy = [0.0] * ranks

    def hop(a_stage: int, b_stage: int, cost: float) -> float:
        """Comm latency for a stage -> stage payload hop."""
        if a_stage % ranks == b_stage % ranks:
            return 0.0             # co-resident chunk: no collective hop
        return cost

    for tick in table:
        for task in sorted(tick):
            if task.kind == "R":
                continue
            # (dependency task, wire latency it arrives with)
            deps: List[Tuple[Task, float]] = []
            if task.kind == "F":
                if task.stage > 0:
                    deps.append((Task("F", task.micro, task.stage - 1),
                                 hop(task.stage - 1, task.stage, comm_cost)))
                for src, dst in route_edges:
                    if dst == task.stage:
                        deps.append((Task("F", task.micro, src),
                                     hop(src, dst, route_comm_cost)))
            elif task.kind == bk:
                if task.stage == n_stages - 1:
                    deps.append((Task("F", task.micro, task.stage), 0.0))
                else:
                    deps.append((Task(bk, task.micro, task.stage + 1),
                                 hop(task.stage + 1, task.stage,
                                     bwd_comm_cost)))
                for src, dst in route_edges:
                    if src == task.stage:
                        deps.append((Task(bk, task.micro, dst),
                                     hop(dst, src, route_comm_cost)))
            elif task.kind == "Bw":
                deps.append((Task("Bx", task.micro, task.stage), 0.0))
            r = task.stage % ranks
            start = max([rank_free[r]]
                        + [finish[d] + h for d, h in deps])
            c = cost_of(task)
            finish[task] = start + c
            rank_free[r] = start + c
            busy[r] += c
            # serialized sends: the producer's compute stream carries the
            # hop, blocking the rank until the wire drains.  The stall
            # counts as bubble (busy stays compute-only), so the spmd
            # bubble fraction >= the mpmd one and a step-time estimate
            # dividing by (1 - bubble) moves the right way.
            if not overlap_comm:
                if task.kind == "F" and task.stage < n_stages - 1 \
                        and (task.stage + 1) % ranks != r and comm_cost:
                    rank_free[r] += comm_cost
                elif task.kind in _BWD_CHAIN and task.stage > 0 \
                        and (task.stage - 1) % ranks != r and bwd_comm_cost:
                    rank_free[r] += bwd_comm_cost
            if not overlap_routes and route_comm_cost:
                # eager route sends: each outgoing value/cotangent hop
                # drains on the producer's stream (the pre-PR 7 story)
                for src, dst in route_edges:
                    if task.kind == "F" and src == task.stage \
                            and dst % ranks != r:
                        rank_free[r] += route_comm_cost
                    elif task.kind == bk and dst == task.stage \
                            and src % ranks != r:
                        rank_free[r] += route_comm_cost
    return max(rank_free, default=0.0), busy


def device_bubble_fraction(table: Sequence[Sequence[Task]], ranks: int,
                           cost_of=None, *, comm_cost: float = 0.0,
                           overlap_comm: bool = False,
                           bwd_comm_cost: Optional[float] = None,
                           route_edges: Sequence[Tuple[int, int]] = (),
                           route_comm_cost: Optional[float] = None,
                           overlap_routes: Optional[bool] = None) -> float:
    """Idle share of the dedicated-device critical path (cost-weighted)."""
    t_end, busy = simulate_device_times(table, ranks, cost_of,
                                        comm_cost=comm_cost,
                                        overlap_comm=overlap_comm,
                                        bwd_comm_cost=bwd_comm_cost,
                                        route_edges=route_edges,
                                        route_comm_cost=route_comm_cost,
                                        overlap_routes=overlap_routes)
    if t_end <= 0:
        return 0.0
    return 1.0 - sum(busy) / (ranks * t_end)


def validate(table: Sequence[Sequence[Task]], m: int, n: int,
             *, ranks: Optional[int] = None,
             checkpoint: bool = False,
             recompute_last_micro: bool = False,
             backward_micro_order: bool = True,
             forward_only: bool = False) -> None:
    """Assert the schedule respects every dependency in the paper's §2 graph.

    ``n`` is the number of (global) stages; ``ranks`` the number of
    executing devices (defaults to ``n``; chunked tables pass the physical
    rank count so per-rank single-task-per-tick is enforced across chunks).

    Raises AssertionError on: missing/duplicate tasks, F(i,j) before
    F(i,j-1), a backward-chain task before its successor stage's,
    per-stage micro-batch order violations (F(i+1,j) before F(i,j) /
    B(i-1,j) before B(i,j), the dashed arrows of Fig. 2), a B(i,j) without
    its R(i,j) earlier in the same stage, or — for split-backward tables —
    a ``Bw(i,j)`` missing or preceding its ``Bx(i,j)``.

    ``backward_micro_order=False`` relaxes the B-side dashed-arrow order:
    1F1B deliberately drains early backwards (B[i] before B[i+1] at a
    stage), which is a *schedule choice* in GPipe, not a data dependency.

    ``forward_only=True`` validates an inference / autodiff-backward plan:
    the table must cover every F task and contain no backward at all (the
    reverse clock-cycle is induced outside the table).
    """
    ranks = n if ranks is None else ranks
    seen = {}
    order = 0
    for tick in table:
        ranks_this_tick = set()
        for t in tick:
            assert t not in seen, f"duplicate {t}"
            assert 0 <= t.stage < n, f"{t} stage out of range (n={n})"
            key = (t.stage % ranks, t.kind in ("B", "Bx", "Bw"), t.kind == "R")
            assert key not in ranks_this_tick, \
                f"rank {t.stage % ranks} runs two {t.kind}-side tasks in one tick"
            ranks_this_tick.add(key)
            seen[t] = order
        order += 1
    have = set(seen)
    split = any(t.kind in ("Bx", "Bw") for t in have)
    bk = "Bx" if split else "B"
    expect_f = {Task("F", i, j) for i in range(m) for j in range(n)}
    assert expect_f <= have, f"missing forwards: {sorted(expect_f - have)[:4]}"
    if forward_only:
        assert not any(t.kind != "F" for t in have), \
            "forward-only table contains backward tasks"
    else:
        expect_b = {Task(bk, i, j) for i in range(m) for j in range(n)}
        assert expect_b <= have, \
            f"missing backwards: {sorted(expect_b - have)[:4]}"
        if split:
            expect_w = {Task("Bw", i, j) for i in range(m) for j in range(n)}
            assert expect_w <= have, \
                f"missing weight grads: {sorted(expect_w - have)[:4]}"
            assert not any(t.kind == "B" for t in have), \
                "split-backward table mixes fused B with Bx/Bw"
    for i in range(m):
        for j in range(n):
            if forward_only:
                if j > 0:
                    assert seen[Task("F", i, j - 1)] < seen[Task("F", i, j)]
                if i > 0:
                    assert seen[Task("F", i - 1, j)] < seen[Task("F", i, j)]
                continue
            assert seen[Task("F", i, j)] < seen[Task(bk, i, j)], \
                f"F[{i},{j}] must precede {bk}[{i},{j}]"
            if split:
                assert seen[Task("Bx", i, j)] < seen[Task("Bw", i, j)], \
                    f"Bx[{i},{j}] must precede Bw[{i},{j}]"
            if j > 0:
                assert seen[Task("F", i, j - 1)] < seen[Task("F", i, j)]
                assert seen[Task(bk, i, j)] < seen[Task(bk, i, j - 1)]
            if i > 0:
                assert seen[Task("F", i - 1, j)] < seen[Task("F", i, j)], \
                    f"micro-batch order: F[{i-1},{j}] !< F[{i},{j}]"
                if backward_micro_order:
                    assert seen[Task(bk, i, j)] < seen[Task(bk, i - 1, j)], \
                        f"micro-batch order: {bk}[{i},{j}] !< {bk}[{i-1},{j}]"
            if checkpoint:
                needs_r = recompute_last_micro or i != m - 1
                if needs_r:
                    r = Task("R", i, j)
                    assert r in seen and seen[r] <= seen[Task("B", i, j)], \
                        f"{r} must precede B[{i},{j}]"
