"""Skip connections across pipeline stages — the paper's §3.3 "portals".

A tensor produced at stage ``src`` and consumed at stage ``dst > src + 1``
breaks the pure-sequential assumption.  torchgpipe offers two behaviours:

* **threaded** (the symptomatic §3.3 case): the tensor is packed into every
  intermediate stage's input/output, i.e. copied hop-by-hop through all
  devices in between.  In our SPMD pipeline this is a slot in the main carry
  that travels with its micro-batch through every ``collective-permute`` hop.

* **portals** (§3.3.1, PortalBlue/Orange/Copy): the tensor is sent *directly*
  from ``src`` to ``dst``.  Here that is a dedicated single-pair
  ``collective-permute([(src, dst)])`` issued at the production tick, plus a
  destination-side ring buffer that holds the value until the owning
  micro-batch arrives.  Intermediate *stages* spend no memory bandwidth or
  kernel time on the tensor (on a physical ring the bits still traverse
  intermediate links, exactly as they traverse PCIe switches in the paper's
  setting — the win is freeing the intermediate devices, not the wires).

Timing: the value for micro-batch ``i`` is produced at ``src`` during tick
``τ = i + src`` and pushed into the destination ring at the end of that tick.
It is consumed at ``dst`` during tick ``i + dst = τ + (dst - src)``; between
push and consume the ring advances ``dst - src - 1`` more times, so the value
is read from slot ``dst - src - 1`` of a ring of depth ``dst - src``.

Multi-consumer skips (e.g. whisper's encoder memory feeding every decoder
stage) use one ring/permute per destination in portal mode but a single
threaded slot otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SkipSpec:
    """One skip value, produced at ``src_stage``, consumed at ``dsts``."""
    name: str
    src_stage: int
    dsts: Tuple[int, ...]

    def __post_init__(self):
        if not self.dsts:
            raise ValueError(f"skip {self.name}: needs at least one dst")
        for d in self.dsts:
            if d <= self.src_stage:
                raise ValueError(f"skip {self.name}: dst {d} must be > src "
                                 f"{self.src_stage}")

    def depth(self, dst: int) -> int:
        return dst - self.src_stage


def ring_init(spec: SkipSpec, proto) -> Dict[int, object]:
    """Per-destination ring buffers (portal mode)."""
    return {
        dst: jax.tree.map(
            lambda p: jnp.zeros((spec.depth(dst),) + tuple(p.shape),
                                jnp.dtype(p.dtype)), proto)
        for dst in spec.dsts
    }


def ring_push(ring, value):
    """Shift one slot and insert ``value`` at slot 0 (end-of-tick)."""
    def push(r, v):
        if r.shape[0] == 1:
            return v[None]
        return jnp.concatenate([v[None].astype(r.dtype), r[:-1]], axis=0)
    return jax.tree.map(push, ring, value)


def ring_read(spec: SkipSpec, dst: int, ring):
    """Value consumed at ``dst`` this tick (slot depth-1 = oldest)."""
    return jax.tree.map(lambda r: r[spec.depth(dst) - 1], ring)


def portal_sends(spec: SkipSpec, value, axis_name: str):
    """PortalCopy: one direct single-pair transfer per destination.

    Returns {dst: received_value}; on non-destination ranks ppermute yields
    zeros, which the ring absorbs harmlessly (only the true dst reads it).
    """
    out = {}
    for dst in spec.dsts:
        out[dst] = jax.tree.map(
            lambda v: jax.lax.ppermute(v, axis_name,
                                       [(spec.src_stage, dst)]), value)
    return out
