"""Skip connections across pipeline stages — the paper's §3.3 "portals".

A tensor produced at stage ``src`` and consumed at stage ``dst > src + 1``
breaks the pure-sequential assumption.  torchgpipe offers two behaviours,
both of which lower to static transfer ROUTES in the unified schedule plan
(:func:`repro.core.plan.lower_tasks`; executed by
:func:`repro.core.pipeline.run_pipeline_tasks`):

* **threaded** (the symptomatic §3.3 case): the tensor is relayed hop-by-hop
  through every intermediate stage — each relay rank parks the arriving
  value and re-sends it on its own F tick, so the intermediate devices
  spend memory bandwidth and a ``collective-permute`` hop on it (the cost
  the ablation benchmark measures).

* **portals** (§3.3.1, PortalBlue/Orange/Copy): the tensor is sent
  *directly* from ``src`` to ``dst`` with a dedicated single-pair
  ``collective-permute([(src, dst)])`` at the production tick.  The
  destination parks it in a plan-allocated buffer slot until the owning
  micro-batch's forward consumes it; intermediate *stages* spend no memory
  bandwidth or kernel time on the tensor (on a physical ring the bits still
  traverse intermediate links, exactly as they traverse PCIe switches in
  the paper's setting — the win is freeing the intermediate devices, not
  the wires).

Timing invariant (proved by ``tests/test_skip.py`` host-side): the value
for micro-batch ``i`` is produced at ``src`` during ``F(i, src)``'s tick
and consumed at ``dst`` during ``F(i, dst)``'s tick, so on the forward
wavefront at most ``SkipSpec.depth(dst) = dst - src`` values are parked at
once — the legacy rotating-ring depth, now an allocator output instead of
an assumption.  Under fused F+B schedules the destination keeps the value
parked until ``B(i, dst)``'s recompute, and a mirrored reverse route
carries the skip cotangent back to seed ``B(i, src)``.

Multi-consumer skips (e.g. whisper's encoder memory feeding every decoder
stage) lower to one route per destination; their backward cotangents sum
at the producer in fixed route order, keeping gradients bitwise-stable
across schedules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class SkipSpec:
    """One skip value, produced at ``src_stage``, consumed at ``dsts``."""
    name: str
    src_stage: int
    dsts: Tuple[int, ...]

    def __post_init__(self):
        if not self.dsts:
            raise ValueError(f"skip {self.name}: needs at least one dst")
        for d in self.dsts:
            if d <= self.src_stage:
                raise ValueError(f"skip {self.name}: dst {d} must be > src "
                                 f"{self.src_stage}")

    def depth(self, dst: int) -> int:
        return dst - self.src_stage
