"""Per-micro-batch gradient checkpointing (paper §3.2.4) as remat policies.

torchgpipe implements checkpointing as a pair of autograd functions
(``Checkpoint``/``Recompute``) sharing memory so that the recomputation
``F'_{i,j}`` can be scheduled concurrently with the copy of ``dx_i^j``.  Under
XLA the same task decomposition is produced by wrapping each per-tick stage
application in :func:`jax.checkpoint`: autodiff then emits the rematerialized
forward immediately before the stage backward, and XLA's async
``collective-permute-start/done`` pairs overlap the recompute with the
gradient copy — the shared-memory trick is what the compiler does natively.

Policies:
  * ``none`` — no remat: the scan stashes whatever XLA keeps (baseline).
  * ``full`` — the paper's setting: store only the stage boundary input,
    recompute everything in backward.
  * ``dots`` — store matmul outputs only (jax checkpoint_dots) — beyond-paper
    middle ground.
  * ``dots_no_batch`` — checkpoint_dots_with_no_batch_dims (cheaper saves).

Split-backward residual handling (``ParallelConfig.residuals``) crosses with
the policy: under ``residuals="reuse"`` the fused executor stashes exactly
the values the policy-wrapped vjp SAVES on the Bx tick and re-reads them on
the Bw tick, so the policy decides the stash-size / Bw-recompute trade:
``none`` stashes every residual the weight grad needs (Bw runs no forward at
all), ``dots`` stashes matmul outputs (Bw recomputes only elementwise ops),
and ``full`` degenerates to recompute semantics (the vjp saves only the
boundary inputs, which are already parked — nothing to stash, Bw
rematerializes inside the pullback).
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.configs.base import REMAT_POLICIES, RESIDUAL_MODES

POLICIES = REMAT_POLICIES


def wrap_stage(stage_fn: Callable, policy: str) -> Callable:
    """Wrap a per-tick stage application according to the remat policy."""
    if policy == "none":
        return stage_fn
    if policy == "full":
        return jax.checkpoint(stage_fn)
    if policy == "dots":
        return jax.checkpoint(
            stage_fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy == "dots_no_batch":
        return jax.checkpoint(
            stage_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {policy!r}; want one of {POLICIES}")


def wrap_for_residuals(fn: Callable, policy: str, residuals: str) -> Callable:
    """Wrap the function the fused executor vjp's on a backward tick.

    ``residuals="recompute"`` leaves ``fn`` bare: the whole vjp lives inside
    one tick, XLA's DCE prunes the unused cotangent half, and nothing
    crosses ticks — the remat policy is irrelevant there.  With
    ``residuals="reuse"`` the Bx tick's pullback leaves ARE the cross-tick
    residual stash, so the policy-wrapped vjp decides what is stashed (see
    module docstring).
    """
    if residuals not in RESIDUAL_MODES:
        raise ValueError(f"unknown residuals mode {residuals!r}; "
                         f"want one of {RESIDUAL_MODES}")
    if residuals == "recompute":
        return fn
    return wrap_stage(fn, policy)


def wrap_stage_for_micro(stage_fn: Callable, policy: str, *, micro: int,
                         n_micro: int, remat_last_micro: bool) -> Callable:
    """Per-micro-batch wrap used by the *unrolled* schedule.

    Implements the paper's §2.1 optimization: the recompute of each stage's
    last micro-batch ``F'_{m,j}`` saves no memory (it is the stage's final
    forward, its activations can be kept) and only slows the pipeline, so it
    is elided — unless ``remat_last_micro`` forces it (the paper does so for
    the m=1 speed-benchmark comparison, footnote 5).
    """
    if policy == "none":
        return stage_fn
    if micro == n_micro - 1 and not remat_last_micro:
        return stage_fn
    return wrap_stage(stage_fn, policy)
