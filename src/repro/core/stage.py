"""Layer -> stage assembly: stacked (homogeneous) and switch (heterogeneous).

torchgpipe requires only "a sequence of layers" and lets the balance module
choose the partition.  Two SPMD-compatible stage program forms:

* **Stacked** (homogeneous families — every transformer LM here): all blocks
  share one parameter structure, stacked to ``[n_stages, L_per_stage, ...]``
  with the leading axis sharded over ``pipe``; a stage scans (or unrolls) its
  ``L_per_stage`` slice.  Layer counts that do not divide evenly are padded
  with *identity* layers: a per-layer ``mask`` constant multiplies the block's
  residual delta, so padded layers are exact identities and receive exactly
  zero gradient.  Pad FLOPs remain in the compiled HLO and are charged
  honestly to the roofline's MODEL/HLO ratio.

* **Switch** (heterogeneous — U-Net / AmoebaNet stages with different channel
  counts): each stage's parameter pytree is flattened into one fp32 buffer,
  padded to the max stage size, and stacked ``[n_stages, max_flat]``; inside
  the SPMD program ``lax.switch(stage_idx, branches)`` unpacks the buffer
  with static shapes per branch and runs that stage's own code.  The carried
  activation is likewise a flat padded buffer (stage boundaries differ in
  shape).  Each rank stores only its own stage's buffer — memory scales as
  the paper's per-device placement — while every branch's *code* exists on
  every rank (an SPMD fact of life; runtime executes one branch).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Homogeneous stacked stages
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageLayout:
    """Layer -> (stage, slot) assignment for the stacked representation.

    ``slot_layer[s, l]`` is the GLOBAL layer index living at stage ``s``,
    slot ``l`` (``-1`` for identity padding); ``mask`` is its 1.0/0.0
    float view (what the blocks gate their residual delta with).  With a
    ``partition`` (per-stage layer counts from ``core.balance``) stages
    hold contiguous, possibly non-uniform runs of layers padded to the
    largest stage; without one the legacy uniform ceil layout is
    reproduced exactly (front-to-back flat fill, padding in the tail
    stages).
    """
    L_per_stage: int
    mask: np.ndarray              # [n_stages, L] float32
    slot_layer: np.ndarray        # [n_stages, L] int32, -1 = padding
    sizes: Tuple[int, ...]        # real layers per stage (sums to n_layers)
    bounds: Tuple[int, ...]       # cumulative: stage s owns [b[s], b[s+1])

    def stage_of(self, layer: int) -> int:
        """Stage hosting GLOBAL layer index ``layer``."""
        for s in range(len(self.sizes)):
            if self.bounds[s] <= layer < self.bounds[s + 1]:
                return s
        raise ValueError(f"layer {layer} outside [0, {self.bounds[-1]})")

    def scatter(self, per_layer: np.ndarray, fill) -> np.ndarray:
        """Spread a length-``n_layers`` per-layer array onto the
        [n_stages, L] slot grid; padding slots take ``fill``."""
        per_layer = np.asarray(per_layer)
        out = np.full((len(self.sizes), self.L_per_stage), fill,
                      per_layer.dtype)
        valid = self.slot_layer >= 0
        out[valid] = per_layer[self.slot_layer[valid]]
        return out


def partition_layout(n_layers: int, n_stages: int,
                     partition: Optional[Sequence[int]] = None) -> StageLayout:
    """Build the stacked-stage layout, uniform or balance-partitioned.

    ``partition`` is per-stage layer counts (``core.balance`` output:
    contiguous, len == n_stages, sums to n_layers); ``None``/empty keeps
    the legacy uniform ceil layout (identical mask to :func:`pad_layout`).
    """
    if partition:
        sizes = tuple(int(p) for p in partition)
        if len(sizes) != n_stages:
            raise ValueError(f"partition has {len(sizes)} entries for "
                             f"{n_stages} stages")
        if sum(sizes) != n_layers:
            raise ValueError(f"partition {sizes} sums to {sum(sizes)}, "
                             f"model has {n_layers} layers")
    else:
        L = -(-n_layers // n_stages)  # ceil
        sizes = tuple(min(L, max(0, n_layers - s * L))
                      for s in range(n_stages))
    Lp = max(max(sizes), 1)
    bounds = [0]
    for sz in sizes:
        bounds.append(bounds[-1] + sz)
    slot = np.full((n_stages, Lp), -1, np.int32)
    for s, sz in enumerate(sizes):
        slot[s, :sz] = np.arange(bounds[s], bounds[s] + sz)
    mask = (slot >= 0).astype(np.float32)
    return StageLayout(Lp, mask, slot, sizes, tuple(bounds))


def pad_layout(n_layers: int, n_stages: int) -> Tuple[int, np.ndarray]:
    """Uniform layers-per-stage with identity padding (legacy wrapper).

    Returns (L_per_stage, mask[n_stages, L_per_stage]) where mask is 1.0 for
    real layers.  Real layers fill stages front-to-back; padding lands at the
    end of the later stages.
    """
    lay = partition_layout(n_layers, n_stages)
    return lay.L_per_stage, lay.mask


def stack_layer_params(layer_params: Sequence[Any], n_stages: int,
                       partition: Optional[Sequence[int]] = None) -> Any:
    """Stack per-layer pytrees (length ≤ n_stages*L) into [n_stages, L, ...].

    Missing (padding) layers are zero-filled.  With ``partition`` each
    stage's slots hold its own contiguous layer run (non-uniform cuts from
    ``core.balance``); without, the legacy flat front-to-back fill.
    """
    lay = partition_layout(len(layer_params), n_stages, partition)
    proto = layer_params[0]
    pad = jax.tree.map(jnp.zeros_like, proto)
    flat_slots = lay.slot_layer.reshape(-1)
    full = [layer_params[k] if k >= 0 else pad for k in flat_slots]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *full)
    return jax.tree.map(
        lambda a: a.reshape((n_stages, lay.L_per_stage) + a.shape[1:]),
        stacked)


def scan_layers(layer_apply: Callable, stage_params, x, *extra,
                unroll: bool = False):
    """Apply a stage's stacked layers in sequence.

    ``layer_apply(one_layer_params, x, *extra) -> x``; stage_params leaves
    have leading [L_per_stage].
    """
    leaves = jax.tree.leaves(stage_params)
    L = leaves[0].shape[0] if leaves else 0
    if unroll:
        for l in range(L):
            x = layer_apply(jax.tree.map(lambda a: a[l], stage_params), x, *extra)
        return x

    def body(x, lp):
        return layer_apply(lp, x, *extra), None

    x, _ = jax.lax.scan(body, x, stage_params)
    return x


# ---------------------------------------------------------------------------
# Heterogeneous switch stages
# ---------------------------------------------------------------------------

@dataclass
class FlatStage:
    """One heterogeneous stage: its apply fn + the shapes needed to unpack."""
    apply: Callable            # apply(params_pytree, x_pytree, ctx) -> y_pytree
    params_treedef: Any
    params_shapes: List[Tuple[Tuple[int, ...], Any]]   # [(shape, dtype)]
    in_proto: Any              # pytree of ShapeDtypeStruct (stage input)
    out_proto: Any             # pytree of ShapeDtypeStruct (stage output)


def flatten_params(params) -> Tuple[jnp.ndarray, Any, List]:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    shapes = [(tuple(l.shape), l.dtype) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)
    return flat, treedef, shapes


def unflatten_params(flat, treedef, shapes):
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def pack_buffer(tree, size: int) -> jnp.ndarray:
    """Flatten activation pytree into a padded fp32 buffer of ``size``."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(l.shape[0], -1).astype(jnp.float32)
                            for l in leaves], axis=1)
    pad = size - flat.shape[1]
    if pad < 0:
        raise ValueError(f"buffer too small: need {flat.shape[1]}, have {size}")
    return jnp.pad(flat, ((0, 0), (0, pad)))


def unpack_buffer(buf, proto):
    leaves, treedef = jax.tree_util.tree_flatten(proto)
    out, off = [], 0
    b = buf.shape[0]
    for l in leaves:
        n = int(np.prod(l.shape[1:]))
        out.append(buf[:, off:off + n].reshape((b,) + tuple(l.shape[1:]))
                   .astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def buffer_elems(proto) -> int:
    """Per-example element count of a stage-boundary pytree."""
    return int(sum(np.prod(l.shape[1:]) for l in jax.tree.leaves(proto)))


def build_switch_program(stages: Sequence[FlatStage]):
    """Build (stacked_flat_params, stage_apply) for the pipeline runner.

    The carried activation is {"buf": [mb, max_elems] fp32}; each branch
    unpacks with its own static shapes.
    """
    n = len(stages)
    max_elems = max(buffer_elems(s.in_proto) for s in stages)
    max_elems = max(max_elems, max(buffer_elems(s.out_proto) for s in stages))

    def stack(flat_list):
        size = max(f.shape[0] for f in flat_list)
        return jnp.stack([jnp.pad(f, (0, size - f.shape[0])) for f in flat_list])

    def make_branch(k: int):
        st = stages[k]

        def branch(flat_params, buf, ctx):
            p = unflatten_params(flat_params, st.params_treedef, st.params_shapes)
            x = unpack_buffer(buf, st.in_proto)
            y = st.apply(p, x, ctx)
            return pack_buffer(y, max_elems)
        return branch

    branches = [make_branch(k) for k in range(n)]

    def stage_apply_buf(flat_params, buf, stage_idx, ctx):
        return jax.lax.switch(stage_idx, branches, flat_params, buf, ctx)

    return stack, stage_apply_buf, max_elems
