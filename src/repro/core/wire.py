"""On-the-wire codec specs + byte accounting for pipeline comm traffic.

Everything the pipeline ships between ranks falls into one of three
payload classes:

* ``chain`` — the boundary activation riding the forward chain
  ``ppermute`` (rank j -> j+1, one carry per F tick below the last stage);
* ``cotangent`` — the backward chain carry (rank j -> j-1) AND the
  mirrored skip-route cotangents (dst -> src);
* ``portal`` — skip/portal route *values* (src -> dst, plus threaded
  relay hops).

A :class:`WireSpec` picks a codec per class:

* ``fp32``    — identity: ship the producing dtype untouched (bitwise
  lossless, the reference mode);
* ``bf16``    — downcast to bfloat16 at the latch, upcast at arrival
  (half the bytes; exact on values already bf16-representable, i.e.
  lossless for bf16-cast models);
* ``int8-ef`` — blockwise int8 quantization with per-block fp32 scales
  and a per-(rank, stream) error-feedback residual added to the next
  payload of the same stream — the EF-SGD construction of
  ``runtime.compression`` generalized from DP gradients to wire traffic
  (~4x fewer bytes; lossy, bounded by the EF residual).

The spec is lowered into the plan IR (``TaskPlan.wire``) so both
executors encode at the latch and decode at the arrival tick, and the
byte accounting here prices the device model's comm term from
``hardware.yaml``'s link bandwidth.  This module is dependency-light
(numpy only) — the jax codec kernels live in ``core.pipeline`` /
``runtime.compression``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional

import numpy as np

#: codecs a payload class can ride the wire as
WIRE_CODECS = ("fp32", "bf16", "int8-ef")

#: payload classes a WireSpec prices independently
PAYLOAD_CLASSES = ("chain", "portal", "cotangent")


def _check_codec(codec: str) -> str:
    if codec not in WIRE_CODECS:
        raise ValueError(f"unknown wire codec {codec!r}; "
                         f"want one of {WIRE_CODECS}")
    return codec


@dataclass(frozen=True)
class WireSpec:
    """Per-payload-class wire precision for pipeline comm traffic."""
    chain: str = "fp32"
    portal: str = "fp32"
    cotangent: str = "fp32"
    block: int = 256          # int8-ef quantization block (elements)

    def __post_init__(self):
        for cls_ in PAYLOAD_CLASSES:
            _check_codec(getattr(self, cls_))
        if self.block < 1:
            raise ValueError(f"need block >= 1, got {self.block}")

    @property
    def lossless(self) -> bool:
        """True when every class ships fp32 (bitwise vs an unwired run).
        ``bf16`` is additionally lossless on bf16-cast models, but that
        depends on the model dtype, not the spec alone."""
        return all(getattr(self, c) == "fp32" for c in PAYLOAD_CLASSES)

    @property
    def stateful(self) -> bool:
        """True when any class carries error-feedback state (int8-ef)."""
        return any(getattr(self, c) == "int8-ef" for c in PAYLOAD_CLASSES)

    @property
    def name(self) -> str:
        """Canonical string form ``parse`` round-trips."""
        vals = {c: getattr(self, c) for c in PAYLOAD_CLASSES}
        if len(set(vals.values())) == 1:
            return next(iter(vals.values()))
        return ",".join(f"{c}={v}" for c, v in vals.items())

    @classmethod
    def parse(cls, spec: "WireSpec | str | None") -> "WireSpec":
        """``"bf16"`` (uniform) or ``"chain=bf16,portal=fp32,..."``."""
        if spec is None:
            return cls()
        if isinstance(spec, WireSpec):
            return spec
        s = str(spec).strip()
        if not s:
            return cls()
        if "=" not in s:
            c = _check_codec(s)
            return cls(chain=c, portal=c, cotangent=c)
        kw: Dict[str, str] = {}
        for part in s.split(","):
            k, _, v = part.partition("=")
            k, v = k.strip(), v.strip()
            if k not in PAYLOAD_CLASSES:
                raise ValueError(f"unknown wire payload class {k!r}; "
                                 f"want one of {PAYLOAD_CLASSES}")
            kw[k] = _check_codec(v)
        return cls(**kw)

    def with_(self, **kw) -> "WireSpec":
        return replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return {"chain": self.chain, "portal": self.portal,
                "cotangent": self.cotangent, "block": self.block}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WireSpec":
        return cls(chain=d.get("chain", "fp32"),
                   portal=d.get("portal", "fp32"),
                   cotangent=d.get("cotangent", "fp32"),
                   block=int(d.get("block", 256)))


#: the identity spec every plan defaults to
WIRE_FP32 = WireSpec()


def bytes_factor(codec: str, *, block: int = 256) -> float:
    """Wire bytes per fp32-equivalent payload byte under ``codec``.

    fp32 ships 4 bytes/element, bf16 2, int8-ef 1 payload byte plus a
    4-byte fp32 scale per ``block`` elements (~1.016/4 at block=256).
    """
    _check_codec(codec)
    if codec == "fp32":
        return 1.0
    if codec == "bf16":
        return 0.5
    return 0.25 + 1.0 / float(block)


def payload_bytes(codec: str, fp32_bytes: float, *, block: int = 256) -> float:
    """On-the-wire bytes for a payload of ``fp32_bytes`` under ``codec``."""
    return fp32_bytes * bytes_factor(codec, block=block)


def hop_comm_units(fp32_bytes: float, codec: str, link_bytes_per_s: float,
                   unit_s: float, *, block: int = 256) -> float:
    """Bytes-priced comm term: one wire hop in stage-forward units.

    ``fp32_bytes / bandwidth`` seconds, scaled by the codec's byte factor
    and normalized by ``unit_s`` (seconds per stage-forward unit) — the
    term ``simulate_device_times`` / ``schedule_bubble`` consume as
    ``comm_cost``.
    """
    if link_bytes_per_s <= 0 or unit_s <= 0:
        return 0.0
    return payload_bytes(codec, fp32_bytes, block=block) \
        / link_bytes_per_s / unit_s


def plan_wire_report(tplan, carry_bytes: float, *,
                     spec: Optional[WireSpec] = None,
                     skip_bytes: Optional[Dict[str, float]] = None
                     ) -> Dict[str, Any]:
    """Price one step's wire traffic for a lowered plan, in actual bytes.

    Counts every cross-rank hop the executor's collectives carry — chain
    carries (``send_slot``), backward cotangents (``b_send_slot``), and
    route value/cotangent hops (same-rank identity holds are free) — and
    prices each class under ``spec``.  ``skip_bytes`` maps skip-edge
    names to their fp32-equivalent payload bytes (default: one carry).
    Returns per-step / per-tick wire bytes plus the compressed /
    uncompressed ratio the bench tables publish.
    """
    spec = spec or getattr(tplan, "wire", None) or WIRE_FP32
    skip_bytes = skip_bytes or {}
    bk = spec.block
    cross = tplan.n_ranks > 1

    chain_hops = int((tplan.send_slot >= 0).sum()) if cross else 0
    bwd_hops = int((tplan.b_send_slot >= 0).sum()) if cross else 0
    route_val = route_cot = 0.0
    route_val_raw = route_cot_raw = 0.0
    for rt in tplan.routes:
        rb = float(skip_bytes.get(rt.name, carry_bytes))
        if rt.fwd_perm:
            n = int((rt.send != -1).sum())
            route_val += n * payload_bytes(spec.portal, rb, block=bk)
            route_val_raw += n * rb
        if rt.bwd_perm:
            n = int((rt.g_send != -1).sum())
            route_cot += n * payload_bytes(spec.cotangent, rb, block=bk)
            route_cot_raw += n * rb

    chain = chain_hops * payload_bytes(spec.chain, carry_bytes, block=bk)
    cot = bwd_hops * payload_bytes(spec.cotangent, carry_bytes, block=bk)
    raw = (chain_hops + bwd_hops) * carry_bytes \
        + route_val_raw + route_cot_raw
    total = chain + cot + route_val + route_cot
    ticks = max(int(tplan.n_ticks), 1)
    return {
        "wire": spec.name,
        "bytes_per_step": total,
        "bytes_per_tick": total / ticks,
        "fp32_bytes_per_step": raw,
        "ratio": (total / raw) if raw else 1.0,
        "per_class": {"chain": chain, "cotangent": cot + route_cot,
                      "portal": route_val},
        "hops": {"chain": chain_hops, "cotangent_chain": bwd_hops,
                 "route_value": int(sum((rt.send != -1).sum()
                                        for rt in tplan.routes
                                        if rt.fwd_perm)),
                 "route_cotangent": int(sum((rt.g_send != -1).sum()
                                            for rt in tplan.routes
                                            if rt.bwd_perm))},
    }
