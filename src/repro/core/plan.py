"""Lowering: schedule task tables -> per-rank, per-tick static plans.

:mod:`repro.core.schedules` is the single source of truth for execution
order: it builds task tables (lists of ticks, each tick a list of
``Task("F"|"B", micro, stage)``) and proves them against the paper's
dependency graph (``schedules.validate``).  This module lowers a validated
table to the *static* per-rank arrays the compiled tick loop consumes:

* :func:`lower_forward` — the forward-only plan for :func:`run_pipeline`
  (autodiff-backward execution).  ``micro[t, j]`` / ``valid[t, j]`` replace
  the hard-coded ``F_{t-j, j}`` arithmetic of paper Algorithm 1.

* :func:`lower_tasks` — the full F+B plan for the fused scheduler
  (``run_pipeline_tasks``), which executes forwards *and* explicit-VJP
  backwards in one loop.  Besides task kind/micro it allocates three static
  buffer disciplines, all sized at lowering time:

  - an **activation stash** per stage (the paper's "stashed activations"):
    F writes its boundary input, the matching B reads and frees it.  Slots
    are assigned by a free-list walk, so the high-water mark per stage is
    *exactly* ``schedules.peak_stash`` — ``m`` for GPipe, ``min(n - j, m)``
    for 1F1B.  The SPMD buffer depth is the max over stages.
  - a forward **inbox** per rank: the ring shift delivers rank ``j-1``'s
    F output one tick after it is produced, possibly several ticks before
    rank ``j`` consumes it (1F1B interleaves); arrivals park in inbox slots.
  - a backward inbox, symmetric, for cotangents travelling ``j+1 -> j``.

Every array is ``[n_ticks, n]`` host-side numpy, turned into constants of
the compiled program; nothing about the order is decided at runtime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import schedules
from repro.core.schedules import Task


@dataclass(frozen=True)
class ForwardPlan:
    """Forward-only schedule: which F task each rank runs at each tick."""
    micro: np.ndarray       # [T, n] int32 (clamped to [0, m) on bubble ticks)
    valid: np.ndarray       # [T, n] bool
    n_ticks: int
    n_stages: int
    n_micro: int


def lower_forward(m: int, n: int) -> ForwardPlan:
    """Lower the deterministic clock-cycle (Algorithm 1) to plan arrays.

    Bubble entries keep the clamped ``t - j`` index the legacy inline
    arithmetic used, so masked compute is bit-identical to the old loop.
    """
    table = list(schedules.clock_cycles(m, n))
    T = len(table)
    micro = np.zeros((T, n), np.int32)
    valid = np.zeros((T, n), bool)
    for t in range(T):
        for j in range(n):
            micro[t, j] = min(max(t - j, 0), m - 1)
        for task in table[t]:
            assert task.kind == "F"
            micro[t, task.stage] = task.micro
            valid[t, task.stage] = True
    return ForwardPlan(micro, valid, T, n, m)


NOP, FWD, BWD = 0, 1, 2


@dataclass(frozen=True)
class TaskPlan:
    """Full fused-schedule plan (forwards + explicit-VJP backwards)."""
    kind: np.ndarray          # [T, n] 0=NOP 1=F 2=B
    micro: np.ndarray         # [T, n] micro index of the task (0 on NOP)
    stash_slot: np.ndarray    # [T, n] F: slot written; B: slot read; -1 else
    f_recv_slot: np.ndarray   # [T, n] fwd-chain arrival -> inbox slot; -1
    f_read_slot: np.ndarray   # [T, n] F input inbox slot; -1 (stage 0/no F)
    b_recv_slot: np.ndarray   # [T, n] bwd-chain arrival -> inbox slot; -1
    b_read_slot: np.ndarray   # [T, n] B seed inbox slot; -1 (last stage/no B)
    n_ticks: int
    n_stages: int
    n_micro: int
    stash_depth: int          # SPMD stash buffer depth (max over stages)
    f_inbox_depth: int
    b_inbox_depth: int
    per_stage_stash: Tuple[int, ...]   # high-water per stage == peak_stash


class _SlotPool:
    """Free-list slot allocator; tracks the high-water mark."""

    def __init__(self):
        self.free: List[int] = []
        self.next = 0
        self.high = 0

    def alloc(self) -> int:
        if self.free:
            return self.free.pop()
        s = self.next
        self.next += 1
        self.high = max(self.high, self.next)
        return s

    def release(self, slot: int) -> None:
        self.free.append(slot)


def lower_tasks(table: Sequence[Sequence[Task]], m: int, n: int) -> TaskPlan:
    """Lower a validated F/B task table to the fused executor's plan."""
    schedules.validate(table, m, n, checkpoint=False,
                       backward_micro_order=False)
    T = len(table)
    t_of: Dict[Task, int] = {}
    for t, tick in enumerate(table):
        per_stage = set()
        for task in tick:
            if task.kind == "R":
                continue           # recompute is fused into B by the VJP
            assert task.stage not in per_stage, \
                f"tick {t}: stage {task.stage} runs two tasks"
            per_stage.add(task.stage)
            t_of[task] = t

    kind = np.full((T, n), NOP, np.int32)
    micro = np.zeros((T, n), np.int32)
    stash_slot = np.full((T, n), -1, np.int32)
    f_recv = np.full((T, n), -1, np.int32)
    f_read = np.full((T, n), -1, np.int32)
    b_recv = np.full((T, n), -1, np.int32)
    b_read = np.full((T, n), -1, np.int32)

    # --- task kinds + activation stash (per-stage free lists) --------------
    stash_pools = [_SlotPool() for _ in range(n)]
    live: List[Dict[int, int]] = [{} for _ in range(n)]   # stage -> micro->slot
    for t, tick in enumerate(table):
        for task in sorted(tick):
            if task.kind == "R":
                continue
            j = task.stage
            kind[t, j] = FWD if task.kind == "F" else BWD
            micro[t, j] = task.micro
            if task.kind == "F":
                s = stash_pools[j].alloc()
                live[j][task.micro] = s
                stash_slot[t, j] = s
            else:
                s = live[j].pop(task.micro)
                stash_slot[t, j] = s
                stash_pools[j].release(s)
    assert all(not lv for lv in live), "unbalanced stash (missing backwards)"

    # --- inboxes: hold ring-shift arrivals until the consuming tick --------
    def route(edges, recv, read):
        """edges: per-rank list of (arrival_tick, consume_tick)."""
        depth = 0
        for j, rank_edges in enumerate(edges):
            pool = _SlotPool()
            for a, c in sorted(rank_edges):
                assert a <= c, f"rank {j}: arrival {a} after consume {c}"
            # replay in time order: arrivals allocate, consumes free
            events = sorted([(a, 0, c) for a, c in rank_edges])
            slot_of = {}
            for a, _, c in events:
                # free every slot whose consume tick has passed
                for (aa, cc), s in list(slot_of.items()):
                    if cc < a:
                        pool.release(s)
                        del slot_of[(aa, cc)]
                s = pool.alloc()
                slot_of[(a, c)] = s
                recv[a, j] = s
                read[c, j] = s
            depth = max(depth, pool.high)
        return depth

    f_edges: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    b_edges: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for i in range(m):
        for j in range(1, n):
            f_edges[j].append((t_of[Task("F", i, j - 1)] + 1,
                               t_of[Task("F", i, j)]))
        for j in range(n - 1):
            b_edges[j].append((t_of[Task("B", i, j + 1)] + 1,
                               t_of[Task("B", i, j)]))
    f_depth = route(f_edges, f_recv, f_read)
    b_depth = route(b_edges, b_recv, b_read)

    per_stage = tuple(p.high for p in stash_pools)
    assert list(per_stage) == schedules.peak_stash(table, n, m), \
        "stash allocator disagrees with schedules.peak_stash"
    return TaskPlan(kind, micro, stash_slot, f_recv, f_read, b_recv, b_read,
                    T, n, m, max(per_stage), max(f_depth, 1),
                    max(b_depth, 1), per_stage)


def plan_for(schedule: str, m: int, n: int) -> TaskPlan:
    """Build + lower the named schedule ("gpipe" or "1f1b")."""
    if schedule in ("gpipe", "gpipe_tasked"):
        table = schedules.gpipe_schedule(m, n, checkpoint=False)
    elif schedule == "1f1b":
        table = schedules.one_f_one_b_schedule(m, n)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return lower_tasks(table, m, n)
