"""Lowering: schedule task tables -> per-rank, per-tick static event plans.

:mod:`repro.core.schedules` is the single source of truth for execution
order: it builds task tables (lists of ticks, each tick a list of
``Task("F"|"B", micro, stage)``) and proves them against the paper's
dependency graph (``schedules.validate``).  This module lowers a validated
table to the *static* per-rank arrays the compiled tick loop
(:func:`repro.core.pipeline.run_pipeline_tasks`) consumes.  There is exactly
one executor; every workload — plain LM, skip-connection (U-Net / enc-dec),
resident-state serving, streamed inputs — runs a :class:`TaskPlan`.

A plan carries four event families, all resolved at lowering time:

* **tasks** — ``kind[t, j]`` / ``micro[t, j]``: which F/B task rank ``j``
  runs at tick ``t`` (NOP during bubbles).  Forward-only plans
  (``has_backward=False``) contain only F tasks and are what inference /
  autodiff-backward execution lowers to.

* **activation stash** (the paper's "stashed activations"): F writes its
  boundary input, the matching B reads and frees it.  Slots are assigned by
  a per-stage free-list walk, so the high-water mark per stage is *exactly*
  ``schedules.peak_stash`` — ``m`` for GPipe, ``min(n - j, m)`` for 1F1B.
  The SPMD buffer depth is the max over stages; masked slot writes keep
  rank ``j`` inside its own ``per_stage_stash[j]`` prefix, so the
  *structural* footprint (what a per-device allocator would charge) is the
  per-stage bound even though the XLA buffer is uniform.

* **inboxes** — the ring shift delivers rank ``j-1``'s F output one tick
  after it is produced, possibly several ticks before rank ``j`` consumes
  it (1F1B interleaves); arrivals park in inbox slots.  A backward inbox,
  symmetric, holds cotangents travelling ``j+1 -> j``.

* **skip routes** (:class:`RoutePlan`, lowered from ``SkipSpec`` edges,
  paper §3.3): one route per (edge, destination).  Portal mode sends the
  value directly ``src -> dst`` with a single-pair collective-permute;
  threaded mode relays it hop-by-hop through every intermediate rank (the
  §3.3 symptomatic case).  The destination *parks* the value until its
  consuming forward — and, in F+B plans, keeps holding it until the
  consumer's backward so the recompute-under-VJP sees the same operand
  (what ``jax.grad`` through the legacy loop kept alive implicitly as a
  checkpoint residual).  Cotangent routes mirror the value routes in
  reverse, seeding the producer's backward.

* **stream injection** (``stream_rot``) — with ``cfg.stream_inputs`` the
  micro-batches are sharded over pipe and rotated one hop towards stage 0;
  the plan flags exactly the ticks where stage 0 consumes a fresh
  micro-batch, so the rotation count stays aligned with the schedule even
  when stage 0's forwards are not consecutive (1F1B steady state).

Every array is ``[n_ticks, n]`` host-side numpy, turned into constants of
the compiled program; nothing about the order is decided at runtime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import schedules
from repro.core.schedules import Task
from repro.core.skip import SkipSpec

NOP, FWD, BWD = 0, 1, 2

# sentinel for RoutePlan send arrays: transmit the value the stage produced
# THIS tick (skips_out in forward routes, the VJP's skip cotangent in
# backward routes) instead of a parked buffer slot.
SEND_STAGE = -2


@dataclass(frozen=True)
class RoutePlan:
    """Lowered transfer schedule for one (skip edge, destination) flow.

    ``send``/``recv``/``read`` are ``[T, n]`` int32: ``send`` is
    :data:`SEND_STAGE` on the tick a rank transmits its freshly produced
    value, a slot index when it relays a parked value (threaded hops), and
    ``-1`` otherwise; ``recv`` parks the in-flight value into a buffer slot
    the tick after the hop; ``read`` feeds a parked slot to the stage
    compute (the consuming F, and — in F+B plans — the matching B's
    recompute).  ``g_send``/``g_recv``/``g_read`` mirror them for the
    cotangent flowing ``dst -> src``; ``g_read`` marks the producer's B
    tick, where the parked cotangent seeds ``skips_out``'s VJP.
    """
    name: str
    src: int
    dst: int
    threaded: bool
    fwd_perm: Tuple[Tuple[int, int], ...]   # static ppermute pairs, value hop
    bwd_perm: Tuple[Tuple[int, int], ...]   # reverse pairs, cotangent hop
    send: np.ndarray
    recv: np.ndarray
    read: np.ndarray
    g_send: np.ndarray
    g_recv: np.ndarray
    g_read: np.ndarray
    depth: int
    g_depth: int

    @property
    def key(self) -> str:
        return f"{self.name}@{self.dst}"


@dataclass(frozen=True)
class TaskPlan:
    """Full fused-schedule event plan (the only executor input)."""
    kind: np.ndarray          # [T, n] 0=NOP 1=F 2=B
    micro: np.ndarray         # [T, n] micro index of the task (0 on NOP)
    stash_slot: np.ndarray    # [T, n] F: slot written; B: slot read; -1 else
    f_recv_slot: np.ndarray   # [T, n] fwd-chain arrival -> inbox slot; -1
    f_read_slot: np.ndarray   # [T, n] F input inbox slot; -1 (stage 0/no F)
    b_recv_slot: np.ndarray   # [T, n] bwd-chain arrival -> inbox slot; -1
    b_read_slot: np.ndarray   # [T, n] B seed inbox slot; -1 (last stage/no B)
    stream_rot: np.ndarray    # [T] bool: rotate the input stream after tick t
    n_ticks: int
    n_stages: int
    n_micro: int
    stash_depth: int          # SPMD stash buffer depth (max over stages)
    f_inbox_depth: int
    b_inbox_depth: int
    per_stage_stash: Tuple[int, ...]   # high-water per stage == peak_stash
    has_backward: bool = True
    routes: Tuple[RoutePlan, ...] = ()

    def per_stage_stash_bytes(self, bytes_per_micro: int) -> Tuple[int, ...]:
        """Structural activation-stash footprint per stage (not flattened
        to the SPMD max): ``min(n - j, m)`` micro-batches for 1F1B."""
        return tuple(d * bytes_per_micro for d in self.per_stage_stash)


class _SlotPool:
    """Free-list slot allocator; tracks the high-water mark."""

    def __init__(self):
        self.free: List[int] = []
        self.next = 0
        self.high = 0

    def alloc(self) -> int:
        if self.free:
            return self.free.pop()
        s = self.next
        self.next += 1
        self.high = max(self.high, self.next)
        return s

    def release(self, slot: int) -> None:
        self.free.append(slot)


def _alloc_intervals(per_rank: Sequence[Sequence[Tuple[int, int, object]]]):
    """Assign buffer slots to live intervals, one free-list per rank.

    ``per_rank[j]`` is a list of ``(arrive_tick, last_use_tick, tag)``; a
    slot is reusable strictly *after* its last-use tick (arrival parks at
    the start of a tick, reads/sends happen later the same tick, so
    same-tick reuse would clobber a live value).  Returns
    ``({tag: slot}, depth)`` with depth the max high-water over ranks.
    """
    assign: Dict[object, int] = {}
    depth = 0
    for rank_events in per_rank:
        pool = _SlotPool()
        live: List[Tuple[int, object]] = []   # (last_use, tag)
        for a, c, tag in sorted(rank_events, key=lambda e: (e[0], e[1])):
            assert a <= c, f"interval arrives {a} after last use {c}"
            for lu, tg in list(live):
                if lu < a:
                    pool.release(assign[tg])
                    live.remove((lu, tg))
            s = pool.alloc()
            assign[tag] = s
            live.append((c, tag))
        depth = max(depth, pool.high)
    return assign, depth


def _lower_routes(t_of: Dict[Task, int], T: int, m: int, n: int,
                  skips: Sequence[SkipSpec], portals: bool,
                  has_backward: bool) -> Tuple[RoutePlan, ...]:
    """Lower skip edges to per-(edge, dst) transfer schedules."""
    routes = []
    for spec in skips:
        for dst in spec.dsts:
            src = spec.src_stage
            if portals:
                hops = [(src, dst)]
            else:
                hops = [(j, j + 1) for j in range(src, dst)]
            fwd_perm = tuple(hops)
            bwd_perm = tuple((b, a) for a, b in reversed(hops))

            send = np.full((T, n), -1, np.int32)
            recv = np.full((T, n), -1, np.int32)
            read = np.full((T, n), -1, np.int32)
            g_send = np.full((T, n), -1, np.int32)
            g_recv = np.full((T, n), -1, np.int32)
            g_read = np.full((T, n), -1, np.int32)

            iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(n)]
            g_iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(n)]
            relays = [b for _, b in hops[:-1]]       # ranks that re-send
            for i in range(m):
                # ---- value: src -> (relays) -> dst --------------------
                send[t_of[Task("F", i, src)], src] = SEND_STAGE
                prev = src
                for r in relays:
                    arrive = t_of[Task("F", i, prev)] + 1
                    resend = t_of[Task("F", i, r)]
                    iv[r].append((arrive, resend, ("f", i, r)))
                    prev = r
                arrive = t_of[Task("F", i, prev)] + 1
                consume = t_of[Task("F", i, dst)]
                hold = (t_of[Task("B", i, dst)] if has_backward else consume)
                iv[dst].append((arrive, hold, ("f", i, dst)))
                # ---- cotangent: dst -> (relays) -> src ----------------
                if has_backward:
                    g_send[t_of[Task("B", i, dst)], dst] = SEND_STAGE
                    prev = dst
                    for r in reversed(relays):
                        arrive = t_of[Task("B", i, prev)] + 1
                        resend = t_of[Task("B", i, r)]
                        g_iv[r].append((arrive, resend, ("b", i, r)))
                        prev = r
                    arrive = t_of[Task("B", i, prev)] + 1
                    seed = t_of[Task("B", i, src)]
                    g_iv[src].append((arrive, seed, ("b", i, src)))

            assign, depth = _alloc_intervals(iv)
            for i in range(m):
                prev = src
                for r in relays:
                    s = assign[("f", i, r)]
                    recv[t_of[Task("F", i, prev)] + 1, r] = s
                    send[t_of[Task("F", i, r)], r] = s
                    prev = r
                s = assign[("f", i, dst)]
                recv[t_of[Task("F", i, prev)] + 1, dst] = s
                read[t_of[Task("F", i, dst)], dst] = s
                if has_backward:
                    read[t_of[Task("B", i, dst)], dst] = s

            g_depth = 1
            if has_backward:
                g_assign, g_depth = _alloc_intervals(g_iv)
                for i in range(m):
                    prev = dst
                    for r in reversed(relays):
                        s = g_assign[("b", i, r)]
                        g_recv[t_of[Task("B", i, prev)] + 1, r] = s
                        g_send[t_of[Task("B", i, r)], r] = s
                        prev = r
                    s = g_assign[("b", i, src)]
                    g_recv[t_of[Task("B", i, prev)] + 1, src] = s
                    g_read[t_of[Task("B", i, src)], src] = s

            routes.append(RoutePlan(
                spec.name, src, dst, not portals, fwd_perm, bwd_perm,
                send, recv, read, g_send, g_recv, g_read,
                max(depth, 1), max(g_depth, 1)))
    return tuple(routes)


def lower_tasks(table: Sequence[Sequence[Task]], m: int, n: int, *,
                skips: Sequence[SkipSpec] = (), portals: bool = True,
                forward_only: bool = False) -> TaskPlan:
    """Lower a validated task table to the fused executor's event plan."""
    schedules.validate(table, m, n, checkpoint=False,
                       backward_micro_order=False, forward_only=forward_only)
    T = len(table)
    t_of: Dict[Task, int] = {}
    for t, tick in enumerate(table):
        per_stage = set()
        for task in tick:
            if task.kind == "R":
                continue           # recompute is fused into B by the VJP
            assert task.stage not in per_stage, \
                f"tick {t}: stage {task.stage} runs two tasks"
            per_stage.add(task.stage)
            t_of[task] = t

    kind = np.full((T, n), NOP, np.int32)
    micro = np.zeros((T, n), np.int32)
    stash_slot = np.full((T, n), -1, np.int32)
    f_recv = np.full((T, n), -1, np.int32)
    f_read = np.full((T, n), -1, np.int32)
    b_recv = np.full((T, n), -1, np.int32)
    b_read = np.full((T, n), -1, np.int32)

    # --- task kinds + activation stash (per-stage free lists) --------------
    stash_pools = [_SlotPool() for _ in range(n)]
    live: List[Dict[int, int]] = [{} for _ in range(n)]   # stage -> micro->slot
    for t, tick in enumerate(table):
        for task in sorted(tick):
            if task.kind == "R":
                continue
            j = task.stage
            kind[t, j] = FWD if task.kind == "F" else BWD
            micro[t, j] = task.micro
            if forward_only:
                continue
            if task.kind == "F":
                s = stash_pools[j].alloc()
                live[j][task.micro] = s
                stash_slot[t, j] = s
            else:
                s = live[j].pop(task.micro)
                stash_slot[t, j] = s
                stash_pools[j].release(s)
    assert all(not lv for lv in live), "unbalanced stash (missing backwards)"

    # --- inboxes: hold ring-shift arrivals until the consuming tick --------
    def route(edges, recv, read):
        """edges: per-rank list of (arrival_tick, consume_tick)."""
        assign, depth = _alloc_intervals(
            [[(a, c, (j, a, c)) for a, c in rank_edges]
             for j, rank_edges in enumerate(edges)])
        for j, rank_edges in enumerate(edges):
            for a, c in rank_edges:
                s = assign[(j, a, c)]
                recv[a, j] = s
                read[c, j] = s
        return depth

    f_edges: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    b_edges: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for i in range(m):
        for j in range(1, n):
            f_edges[j].append((t_of[Task("F", i, j - 1)] + 1,
                               t_of[Task("F", i, j)]))
        if not forward_only:
            for j in range(n - 1):
                b_edges[j].append((t_of[Task("B", i, j + 1)] + 1,
                                   t_of[Task("B", i, j)]))
    f_depth = route(f_edges, f_recv, f_read)
    b_depth = route(b_edges, b_recv, b_read)

    # --- stream injection: rotate after each tick stage 0 consumes --------
    stream_rot = (kind[:, 0] == FWD).copy()

    per_stage = tuple(p.high for p in stash_pools)
    if not forward_only:
        assert list(per_stage) == schedules.peak_stash(table, n, m), \
            "stash allocator disagrees with schedules.peak_stash"
    routes = _lower_routes(t_of, T, m, n, skips, portals,
                           has_backward=not forward_only)
    return TaskPlan(kind, micro, stash_slot, f_recv, f_read, b_recv, b_read,
                    stream_rot, T, n, m,
                    max(per_stage) if per_stage else 0,
                    max(f_depth, 1), max(b_depth, 1), per_stage,
                    has_backward=not forward_only, routes=routes)


def plan_for(schedule: str, m: int, n: int, *,
             skips: Sequence[SkipSpec] = (),
             portals: bool = True) -> TaskPlan:
    """Build + lower the named schedule.

    ``"gpipe"``/``"gpipe_tasked"`` and ``"1f1b"`` produce full F+B plans
    for the fused executor; ``"gpipe_fwd"`` produces the forward-only
    clock-cycle plan (paper Algorithm 1) that inference and the
    autodiff-backward path execute.
    """
    if schedule == "gpipe_fwd":
        table = [list(tick) for tick in schedules.clock_cycles(m, n)]
        return lower_tasks(table, m, n, skips=skips, portals=portals,
                           forward_only=True)
    if schedule in ("gpipe", "gpipe_tasked"):
        table = schedules.gpipe_schedule(m, n, checkpoint=False)
    elif schedule == "1f1b":
        table = schedules.one_f_one_b_schedule(m, n)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return lower_tasks(table, m, n, skips=skips, portals=portals)
