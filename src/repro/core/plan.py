"""Lowering: schedule task tables -> per-rank, per-tick static event plans.

:mod:`repro.core.schedules` is the single source of truth for execution
order: it builds task tables (lists of ticks, each tick a list of
``Task(kind, micro, stage)`` with ``stage`` a GLOBAL stage index) and proves
them against the paper's dependency graph (``schedules.validate``).  This
module lowers a validated table to the *static* per-rank arrays the compiled
tick loop (:func:`repro.core.pipeline.run_pipeline_tasks`) consumes.  There
is exactly one executor; every workload — plain LM, skip-connection (U-Net /
enc-dec), resident-state serving, streamed inputs — runs a
:class:`TaskPlan`.

A plan carries these event families, all resolved at lowering time:

* **tasks** — ``kind[t, r]`` / ``micro[t, r]`` / ``chunk[t, r]``: which
  task rank ``r`` runs at tick ``t`` (NOP during bubbles).  With
  interleaved virtual stages (``n_chunks > 1``) rank ``r`` hosts global
  stages ``{r, r + R, ...}`` and ``chunk`` selects which of its parameter
  chunks the tick touches.  Backward tasks come in three flavours: fused
  ``BWD`` (input + weight cotangents in one tick), and the split pair
  ``BWD_X`` (input cotangent, on the inter-stage critical path) /
  ``BWD_W`` (weight gradient, filled into bubble ticks).

* **park buffer** (the paper's "stashed activations", donated): the ring
  shift delivers a stage's boundary input one tick after the producer's F;
  the value *parks* in a slot and stays there — the consuming F reads it
  in place and, in F+B plans, the matching backward re-reads the same slot
  for its recompute.  There is no separate inbox→stash copy: the arrival
  buffer IS the stash (buffer donation), so per tick the executor does one
  masked park write instead of a park write plus a stash write, and the
  per-rank high-water (``per_stage_park``) is the true footprint a
  per-device allocator charges — e.g. 0 slots for 1F1B's stage 0 (its
  input is re-gathered from the micro-batch buffer, not stashed).
  ``per_stage_stash`` keeps the schedule-level bound (``m`` for GPipe,
  ``min(n - j, m)`` for 1F1B) for reporting against the paper.

* **backward inbox** — cotangents travelling ``r+1 -> r`` park
  symmetrically; in split-backward plans the seed stays parked after
  ``BWD_X`` reads it so ``BWD_W`` can re-seed the weight-gradient VJP.

* **residual stash** (``residuals="reuse"``, true ZB-H1): on a ``BWD_X``
  tick the executor captures the stage vjp's residuals (what the remat
  policy saves — the values the weight gradient needs) and parks them in a
  donated per-rank residual slot (``resid_write``); the matching ``BWD_W``
  re-reads the slot (``resid_read``) instead of re-running the stage
  forward, and the slot frees at the Bw tick.  Slot intervals are
  allocated next to the park buffer (same free-list allocator); the
  per-rank high-water is ``per_stage_resid`` and
  ``schedules.peak_residuals`` predicts it exactly.  Fused-backward tables
  carry no residual events (nothing crosses ticks).

* **skip routes** (:class:`RoutePlan`, lowered from ``SkipSpec`` edges,
  paper §3.3): one route per (edge, destination).  Portal mode sends the
  value directly ``src -> dst`` with a single-pair collective-permute
  (an identity hold when both stages live on one rank); threaded mode
  relays it hop-by-hop through every intermediate stage.  The destination
  parks the value until its consuming forward and keeps holding it through
  the consumer's backward(s); cotangent routes mirror the value routes in
  reverse, seeding the producer's backward — and, split, its ``BWD_W``.

* **stream injection** — with ``cfg.stream_inputs`` the micro-batches are
  sharded over pipe and rotated one hop towards stage 0; ``stream_slot``
  names the shard slot rank 0 consumes at each chunk-0 forward and
  ``stream_rot`` flags the rotation ticks.

* **segments** — maximal runs of ticks that use the same *branch set*
  (e.g. GPipe's pure-F fill, 1F1B's mixed steady state, a ZB drain of
  ``BWD_W`` only).  The executor runs one scan per segment with the
  ``lax.switch`` pruned to exactly the branches the segment uses and the
  bookkeeping (grad writes, stream rotation, chain permutes) elided when
  the segment provably never needs it.  All-rank-NOP ticks are dropped
  entirely at lowering time.

* **chain double buffering** (``send_slot`` / ``b_send_slot``): the clock
  cycle makes every ring send known one tick ahead, so the MPMD executor
  latches a tick's boundary output (the forward carry on ``send_slot``
  ticks, the ``B``/``Bx`` input cotangent on ``b_send_slot`` ticks) into
  a depth-1 send register and ships it at the TOP of the *next* tick —
  the ``ppermute`` then has no data dependency on that tick's stage
  compute, so XLA's scheduler can overlap comm with compute instead of
  serializing compute -> send.  Arrival ticks are unchanged (producer's
  tick + 1), so the values that park are bitwise the ones the eager send
  would have delivered.  The columns hold ``0`` (the register slot — one
  suffices, a latch written at the bottom of tick ``t`` is consumed at
  the top of ``t+1`` before the next write) on shipping ticks and ``-1``
  elsewhere; the last global stage never ships forward, stage 0 never
  ships backward.

Every array is ``[n_ticks, n_ranks]`` host-side numpy, turned into
constants of the compiled program; nothing about the order is decided at
runtime.  :func:`specialize` projects the whole plan onto one rank's
column — the MPMD lowering unit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import parse_schedule
from repro.core import schedules
from repro.core.schedules import Task
from repro.core.skip import SkipSpec
from repro.core.wire import WIRE_FP32, WireSpec

NOP, FWD, BWD, BWD_X, BWD_W = 0, 1, 2, 3, 4

_KIND_OF = {"F": FWD, "B": BWD, "Bx": BWD_X, "Bw": BWD_W}

#: backward flavours that compute input cotangents (ship down the b chain)
BWD_INPUT_KINDS = (BWD, BWD_X)
#: backward flavours that compute weight gradients
BWD_WEIGHT_KINDS = (BWD, BWD_W)
#: every backward flavour (reads the parked activation for its recompute)
BWD_KINDS = (BWD, BWD_X, BWD_W)

#: cap on executor segments: beyond this, adjacent segments are coalesced
#: (their branch sets unioned) to bound trace/compile time.
MAX_SEGMENTS = 8

# sentinel for RoutePlan send arrays: transmit the value the stage produced
# THIS tick (skips_out in forward routes, the VJP's skip cotangent in
# backward routes) instead of a parked buffer slot.
SEND_STAGE = -2


def pipe_ring_perm(n: int, *, reverse: bool = False,
                   ring: bool = False) -> list:
    """Static ppermute pairs for the pipeline chain on ``n`` pipe ranks.

    Forward: ``j -> j+1`` (the boundary-activation hop); ``reverse``:
    ``j -> j-1`` (the cotangent hop).  ``ring`` adds the wraparound pair
    (last -> first, or first -> last reversed) that interleaved chunk
    boundaries ride.  The pipeline executor and any tool reasoning about
    chain collectives (dryrun comm accounting, launch.mesh, tests) share
    this one definition so the wire topology cannot drift between them.
    """
    if reverse:
        return [(i, i - 1) for i in range(1, n)] \
            + ([(0, n - 1)] if ring else [])
    return [(i, i + 1) for i in range(n - 1)] + ([(n - 1, 0)] if ring else [])


@dataclass(frozen=True)
class Segment:
    """One executor phase: ticks [start, stop) sharing a branch set."""
    start: int
    stop: int
    kinds: Tuple[int, ...]        # sorted kind ids present (incl. NOP)


@dataclass(frozen=True)
class RoutePlan:
    """Lowered transfer schedule for one (skip edge, destination) flow.

    ``send``/``recv``/``read`` are ``[T, R]`` int32: ``send`` is
    :data:`SEND_STAGE` on the tick a rank transmits its freshly produced
    value, a slot index when it relays a parked value (threaded hops), and
    ``-1`` otherwise; ``recv`` parks the in-flight value into a buffer slot
    the tick after the hop; ``read`` feeds a parked slot to the stage
    compute (the consuming F and every backward flavour that recomputes
    it).  ``g_send``/``g_recv``/``g_read`` mirror them for the cotangent
    flowing ``dst -> src``; ``g_read`` marks the producer's backward
    tick(s), where the parked cotangent seeds ``skips_out``'s VJP.  Empty
    ``fwd_perm``/``bwd_perm`` mean src and dst share a rank (interleaved
    chunks): the "hop" is an identity hold, no collective.
    """
    name: str
    src: int
    dst: int
    threaded: bool
    fwd_perm: Tuple[Tuple[int, int], ...]   # static ppermute pairs, value hop
    bwd_perm: Tuple[Tuple[int, int], ...]   # reverse pairs, cotangent hop
    send: np.ndarray
    recv: np.ndarray
    read: np.ndarray
    g_send: np.ndarray
    g_recv: np.ndarray
    g_read: np.ndarray
    depth: int
    g_depth: int

    @property
    def key(self) -> str:
        return f"{self.name}@{self.dst}"

    # Ship masks for the double-buffered (mpmd) lowering: a payload that
    # latched on any rank at the bottom of tick t-1 ships at the TOP of
    # tick t, overlapped with tick t's compute — exactly the chain-carry
    # discipline of ``send_slot``.  ``ship[t]`` marks the ticks whose top
    # needs the value hop; ``g_ship`` mirrors it for the cotangent.
    @property
    def ship(self) -> np.ndarray:
        s = np.zeros(self.send.shape[0], bool)
        s[1:] = (self.send[:-1] != -1).any(axis=1)
        return s

    @property
    def g_ship(self) -> np.ndarray:
        s = np.zeros(self.g_send.shape[0], bool)
        s[1:] = (self.g_send[:-1] != -1).any(axis=1)
        return s


@dataclass(frozen=True)
class TaskPlan:
    """Full fused-schedule event plan (the only executor input)."""
    kind: np.ndarray          # [T, R] NOP/FWD/BWD/BWD_X/BWD_W
    micro: np.ndarray         # [T, R] micro index of the task (0 on NOP)
    chunk: np.ndarray         # [T, R] virtual-stage chunk of the task (0 ..)
    park_recv: np.ndarray     # [T, R] ring arrival -> park slot; -1
    park_read: np.ndarray     # [T, R] park slot this tick's task reads; -1
    b_recv: np.ndarray        # [T, R] bwd-chain arrival -> inbox slot; -1
    b_read: np.ndarray        # [T, R] B seed inbox slot (B/Bx and Bw); -1
    fs_slot: np.ndarray       # [T, R] stream-stash slot (F write, B read); -1
    stream_slot: np.ndarray   # [T] stream shard slot rank 0 consumes; -1
    stream_rot: np.ndarray    # [T] bool: rotate the input stream after tick t
    send_slot: np.ndarray     # [T, R] latch fwd carry for next-tick ship; -1
    b_send_slot: np.ndarray   # [T, R] latch bwd cotangent for next ship; -1
    segments: Tuple[Segment, ...]
    n_ticks: int
    n_stages: int             # GLOBAL stages (= n_ranks * n_chunks)
    n_ranks: int
    n_micro: int
    n_chunks: int
    park_depth: int           # SPMD park buffer depth (max over ranks)
    b_inbox_depth: int
    fs_depth: int
    per_stage_stash: Tuple[int, ...]   # schedule-level bound (peak_stash/rank)
    per_stage_park: Tuple[int, ...]    # donated park high-water per rank
    per_stage_b_inbox: Tuple[int, ...] = ()   # bwd-inbox high-water per rank
    per_stage_fs: Tuple[int, ...] = ()        # stream-stash high-water per rank
    has_backward: bool = True
    routes: Tuple[RoutePlan, ...] = ()
    # --- split-backward residual reuse (ZB-H1, residuals="reuse") ---------
    residuals: str = "recompute"       # effective mode ("reuse" only when
    #   the table actually splits backward — fused tables coerce back)
    resid_write: Optional[np.ndarray] = None   # [T, R] BWD_X -> stash slot
    resid_read: Optional[np.ndarray] = None    # [T, R] BWD_W <- stash slot
    resid_depth: int = 0               # SPMD residual buffer depth (max/rank)
    per_stage_resid: Tuple[int, ...] = ()      # residual high-water per rank
    # --- on-the-wire codec (PR 7) -----------------------------------------
    wire: WireSpec = WIRE_FP32         # per-payload-class encode at latch /
    #   decode at arrival; fp32 is the bitwise-lossless identity

    @property
    def stash_depth(self) -> int:
        """Depth of the (uniform SPMD) park buffer the executor allocates."""
        return self.park_depth

    def per_stage_stash_bytes(self, bytes_per_micro: int) -> Tuple[int, ...]:
        """Donated activation footprint per rank: what a per-device
        allocator charges — the park high-water, NOT a flattened max."""
        return tuple(d * bytes_per_micro for d in self.per_stage_park)


class _SlotPool:
    """Free-list slot allocator; tracks the high-water mark."""

    def __init__(self):
        self.free: List[int] = []
        self.next = 0
        self.high = 0

    def alloc(self) -> int:
        if self.free:
            return self.free.pop()
        s = self.next
        self.next += 1
        self.high = max(self.high, self.next)
        return s

    def release(self, slot: int) -> None:
        self.free.append(slot)


def _alloc_intervals(per_rank: Sequence[Sequence[Tuple[int, int, object]]]):
    """Assign buffer slots to live intervals, one free-list per rank.

    ``per_rank[j]`` is a list of ``(arrive_tick, last_use_tick, tag)``; a
    slot is reusable strictly *after* its last-use tick (arrival parks at
    the start of a tick, reads/sends happen later the same tick, so
    same-tick reuse would clobber a live value).  Returns
    ``({tag: slot}, depth, per_rank_high)``.
    """
    assign: Dict[object, int] = {}
    highs: List[int] = []
    for rank_events in per_rank:
        pool = _SlotPool()
        live: List[Tuple[int, object]] = []   # (last_use, tag)
        for a, c, tag in sorted(rank_events, key=lambda e: (e[0], e[1])):
            assert a <= c, f"interval arrives {a} after last use {c}"
            for lu, tg in list(live):
                if lu < a:
                    pool.release(assign[tg])
                    live.remove((lu, tg))
            s = pool.alloc()
            assign[tag] = s
            live.append((c, tag))
        highs.append(pool.high)
    return assign, max(highs, default=0), highs


class _TaskIndex:
    """Tick lookup per (kind-family, micro, stage) for one compacted table."""

    def __init__(self, table: Sequence[Sequence[Task]]):
        self.f: Dict[Tuple[int, int], int] = {}
        self.b: Dict[Tuple[int, int], int] = {}   # fused B or Bx
        self.w: Dict[Tuple[int, int], int] = {}   # Bw (split only)
        for t, tick in enumerate(table):
            for task in tick:
                if task.kind == "F":
                    self.f[(task.micro, task.stage)] = t
                elif task.kind in ("B", "Bx"):
                    self.b[(task.micro, task.stage)] = t
                elif task.kind == "Bw":
                    self.w[(task.micro, task.stage)] = t

    def last_b(self, i: int, s: int) -> int:
        """Tick of the LAST backward reader of (i, s)'s activation."""
        return self.w.get((i, s), self.b.get((i, s), -1))

    def b_ticks(self, i: int, s: int) -> List[int]:
        """Every backward tick that re-reads (i, s)'s operands."""
        out = [self.b[(i, s)]]
        if (i, s) in self.w:
            out.append(self.w[(i, s)])
        return out


def _lower_routes(ix: _TaskIndex, T: int, m: int, ranks: int,
                  skips: Sequence[SkipSpec], portals: bool,
                  has_backward: bool) -> Tuple[RoutePlan, ...]:
    """Lower skip edges to per-(edge, dst) transfer schedules."""
    routes = []
    for spec in skips:
        for dst in spec.dsts:
            src = spec.src_stage

            def rk(s):
                return s % ranks

            if portals:
                hop_stages = [(src, dst)]
            else:
                hop_stages = [(s, s + 1) for s in range(src, dst)]
            fwd_perm = tuple((rk(a), rk(b)) for a, b in hop_stages
                             if rk(a) != rk(b))
            if len(set(fwd_perm)) != len(fwd_perm):
                # a threaded chain spanning more than one chunk ring wraps
                # onto the same physical link twice — one ppermute cannot
                # carry two values over one pair.  Portals avoid this.
                raise NotImplementedError(
                    f"threaded route {spec.name!r} ({src}->{dst}) wraps the "
                    f"rank ring under interleaving; use portals=True")
            bwd_perm = tuple((b, a) for a, b in reversed(fwd_perm))

            send = np.full((T, ranks), -1, np.int32)
            recv = np.full((T, ranks), -1, np.int32)
            read = np.full((T, ranks), -1, np.int32)
            g_send = np.full((T, ranks), -1, np.int32)
            g_recv = np.full((T, ranks), -1, np.int32)
            g_read = np.full((T, ranks), -1, np.int32)

            iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(ranks)]
            g_iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(ranks)]
            relays = [b for _, b in hop_stages[:-1]]     # stages that re-send
            for i in range(m):
                # ---- value: src -> (relays) -> dst --------------------
                send[ix.f[(i, src)], rk(src)] = SEND_STAGE
                prev = src
                for r in relays:
                    arrive = ix.f[(i, prev)] + 1
                    resend = ix.f[(i, r)]
                    iv[rk(r)].append((arrive, resend, ("f", i, r)))
                    prev = r
                arrive = ix.f[(i, prev)] + 1
                consume = ix.f[(i, dst)]
                hold = (ix.last_b(i, dst) if has_backward else consume)
                iv[rk(dst)].append((arrive, hold, ("f", i, dst)))
                # ---- cotangent: dst -> (relays) -> src ----------------
                if has_backward:
                    g_send[ix.b[(i, dst)], rk(dst)] = SEND_STAGE
                    prev = dst
                    for r in reversed(relays):
                        arrive = ix.b[(i, prev)] + 1
                        resend = ix.b[(i, r)]
                        g_iv[rk(r)].append((arrive, resend, ("b", i, r)))
                        prev = r
                    arrive = ix.b[(i, prev)] + 1
                    g_iv[rk(src)].append((arrive, ix.last_b(i, src),
                                          ("b", i, src)))

            assign, depth, _ = _alloc_intervals(iv)
            for i in range(m):
                prev = src
                for r in relays:
                    s = assign[("f", i, r)]
                    recv[ix.f[(i, prev)] + 1, rk(r)] = s
                    send[ix.f[(i, r)], rk(r)] = s
                    prev = r
                s = assign[("f", i, dst)]
                recv[ix.f[(i, prev)] + 1, rk(dst)] = s
                read[ix.f[(i, dst)], rk(dst)] = s
                if has_backward:
                    for tb in ix.b_ticks(i, dst):
                        read[tb, rk(dst)] = s

            g_depth = 1
            if has_backward:
                g_assign, g_depth, _ = _alloc_intervals(g_iv)
                for i in range(m):
                    prev = dst
                    for r in reversed(relays):
                        s = g_assign[("b", i, r)]
                        g_recv[ix.b[(i, prev)] + 1, rk(r)] = s
                        g_send[ix.b[(i, r)], rk(r)] = s
                        prev = r
                    s = g_assign[("b", i, src)]
                    g_recv[ix.b[(i, prev)] + 1, rk(src)] = s
                    for tb in ix.b_ticks(i, src):
                        g_read[tb, rk(src)] = s

            routes.append(RoutePlan(
                spec.name, src, dst, not portals, fwd_perm, bwd_perm,
                send, recv, read, g_send, g_recv, g_read,
                max(depth, 1), max(g_depth, 1)))
    return tuple(routes)


def _segments(kind: np.ndarray) -> Tuple[Segment, ...]:
    """Maximal runs of ticks sharing a branch set, coalesced to a cap."""
    T = kind.shape[0]
    sets = [frozenset(int(k) for k in kind[t]) for t in range(T)]
    segs: List[Tuple[int, int, frozenset]] = []
    for t in range(T):
        if segs and segs[-1][2] == sets[t]:
            segs[-1] = (segs[-1][0], t + 1, segs[-1][2])
        else:
            segs.append((t, t + 1, sets[t]))
    while len(segs) > MAX_SEGMENTS:
        # merge the shortest segment into its shorter neighbour
        li = min(range(len(segs)), key=lambda i: segs[i][1] - segs[i][0])
        ni = li - 1 if li > 0 and (
            li == len(segs) - 1
            or (segs[li - 1][1] - segs[li - 1][0]
                <= segs[li + 1][1] - segs[li + 1][0])) else li + 1
        a, b = sorted((li, ni))
        segs[a] = (segs[a][0], segs[b][1], segs[a][2] | segs[b][2])
        del segs[b]
    return tuple(Segment(s, e, tuple(sorted(ks))) for s, e, ks in segs)


# ---------------------------------------------------------------------------
# MPMD specialization: one rank's column of the plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RankProgram:
    """The plan projected onto ONE rank — the MPMD lowering unit.

    Where the SPMD plan flattens every per-rank quantity to the ring max
    (uniform buffers, union branch sets), a rank program carries exactly
    what *this* rank's column needs: its own tick kinds and slot columns,
    buffer depths equal to its own slot high-water (1F1B's rank 0 parks 0
    slots, not ``max_j``), and segments cut along ITS kind runs — a rank
    whose column is all-F in a window gets a branch-free program there.

    The executor dispatches the per-rank programs under one top-level
    rank-indexed ``lax.switch`` inside the shared ``shard_map`` body; the
    collective skeleton (chain / route permutes, stream rotation) stays
    rank-uniform OUTSIDE the switch — collectives inside per-rank branches
    would deadlock a real device group, so only pure compute specializes.
    One SPMD executable must still physically allocate the ring-max
    buffers; the per-rank depths here are the footprint each rank's
    program *declares* (and a one-program-per-host MPMD deployment would
    allocate), which the bench / dryrun report per rank.
    """
    rank: int
    n_ranks: int
    kind: np.ndarray          # [T] this rank's task kind per tick
    micro: np.ndarray         # [T]
    chunk: np.ndarray         # [T]
    park_recv: np.ndarray     # [T] slot columns, already rank-local: the
    park_read: np.ndarray     # [T] free-list allocator runs one pool per
    b_recv: np.ndarray        # [T] rank, so every slot index in a column
    b_read: np.ndarray        # [T] is < the matching per-rank depth below
    fs_slot: np.ndarray       # [T]
    send_slot: np.ndarray     # [T] latch fwd carry for next-tick ship; -1
    b_send_slot: np.ndarray   # [T]
    resid_write: Optional[np.ndarray]   # [T] (reuse plans only)
    resid_read: Optional[np.ndarray]    # [T]
    segments: Tuple[Segment, ...]       # cuts along THIS rank's kind runs
    n_ticks: int
    park_depth: int           # this rank's park high-water (exact)
    b_inbox_depth: int
    fs_depth: int
    resid_depth: int
    residuals: str

    def branches_in(self, start: int, stop: int) -> Tuple[int, ...]:
        """Exact branch set of this rank's column over ticks [start, stop)."""
        return tuple(sorted(set(int(k) for k in self.kind[start:stop])))

    def buffer_slots(self) -> Dict[str, int]:
        """Slot counts per buffer family this rank's program declares."""
        return {"park": self.park_depth, "b_inbox": self.b_inbox_depth,
                "fs": self.fs_depth, "resid": self.resid_depth}


def specialize(tplan: TaskPlan, rank: int) -> RankProgram:
    """Project the global plan onto ``rank``'s column.

    Slot indices need no renumbering: the plan's free-list allocator
    already runs one pool per rank, so each column's indices are dense in
    ``[0, per_rank_depth)``.  Segments are recomputed from the single
    column, so a window where this rank runs only one kind becomes a
    branch-free segment even when other ranks mix kinds there.
    """
    if not 0 <= rank < tplan.n_ranks:
        raise ValueError(f"rank {rank} out of range (n_ranks="
                         f"{tplan.n_ranks})")
    r = rank

    def col(a):
        return None if a is None else np.ascontiguousarray(a[:, r])

    def depth_of(per_stage, fallback):
        return int(per_stage[r]) if len(per_stage) == tplan.n_ranks \
            else fallback

    prog = RankProgram(
        rank=r, n_ranks=tplan.n_ranks,
        kind=col(tplan.kind), micro=col(tplan.micro), chunk=col(tplan.chunk),
        park_recv=col(tplan.park_recv), park_read=col(tplan.park_read),
        b_recv=col(tplan.b_recv), b_read=col(tplan.b_read),
        fs_slot=col(tplan.fs_slot),
        send_slot=col(tplan.send_slot), b_send_slot=col(tplan.b_send_slot),
        resid_write=col(tplan.resid_write), resid_read=col(tplan.resid_read),
        segments=_segments(tplan.kind[:, r:r + 1]),
        n_ticks=tplan.n_ticks,
        park_depth=depth_of(tplan.per_stage_park, tplan.park_depth),
        b_inbox_depth=depth_of(tplan.per_stage_b_inbox, tplan.b_inbox_depth),
        fs_depth=depth_of(tplan.per_stage_fs, tplan.fs_depth),
        resid_depth=depth_of(tplan.per_stage_resid, tplan.resid_depth),
        residuals=tplan.residuals)
    for name, column, depth in (
            ("park", prog.park_recv, prog.park_depth),
            ("park", prog.park_read, prog.park_depth),
            ("b_inbox", prog.b_recv, prog.b_inbox_depth),
            ("b_inbox", prog.b_read, prog.b_inbox_depth),
            ("fs", prog.fs_slot, prog.fs_depth),
            ("resid", prog.resid_write, prog.resid_depth),
            ("resid", prog.resid_read, prog.resid_depth)):
        if column is not None and column.size and int(column.max()) >= 0:
            assert int(column.max()) < depth, \
                (f"rank {r}: {name} slot {int(column.max())} outside the "
                 f"declared depth {depth}")
    return prog


def lower_tasks(table: Sequence[Sequence[Task]], m: int, n: int, *,
                ranks: Optional[int] = None,
                skips: Sequence[SkipSpec] = (), portals: bool = True,
                forward_only: bool = False,
                residuals: str = "recompute",
                wire: Optional[WireSpec] = None) -> TaskPlan:
    """Lower a validated task table to the fused executor's event plan.

    ``n`` is the number of GLOBAL stages; ``ranks`` (default ``n``) the
    number of executing devices — pass ``ranks < n`` for interleaved
    tables, where rank ``r`` hosts the ``n // ranks`` chunks
    ``{r, r + ranks, ...}``.  ``residuals="reuse"`` additionally allocates
    the Bx->Bw residual-stash slots for split-backward tables (coerced back
    to ``"recompute"`` when the table has no ``Bw`` — there is nothing to
    reuse across ticks in a fused backward).  ``wire`` selects the
    on-the-wire codec the executor applies at latch/arrival (default: the
    lossless fp32 identity).
    """
    if residuals not in ("recompute", "reuse"):
        raise ValueError(f"unknown residuals mode {residuals!r}; "
                         "want 'recompute' or 'reuse'")
    wire = WireSpec.parse(wire) if wire is not None else WIRE_FP32
    R = n if ranks is None else ranks
    if n % R:
        raise ValueError(f"stages ({n}) must tile ranks ({R})")
    v = n // R
    schedules.validate(table, m, n, ranks=R, checkpoint=False,
                       backward_micro_order=False, forward_only=forward_only)
    # compact: all-rank-NOP ticks cost a full executor iteration for no work
    table = [tick for tick in table
             if any(t.kind != "R" for t in tick)]
    T = len(table)
    ix = _TaskIndex(table)

    kind = np.full((T, R), NOP, np.int32)
    micro = np.zeros((T, R), np.int32)
    chunk = np.zeros((T, R), np.int32)
    park_recv = np.full((T, R), -1, np.int32)
    park_read = np.full((T, R), -1, np.int32)
    b_recv = np.full((T, R), -1, np.int32)
    b_read = np.full((T, R), -1, np.int32)
    fs_slot = np.full((T, R), -1, np.int32)
    stream_slot = np.full((T,), -1, np.int32)

    for t, tick in enumerate(table):
        for task in sorted(tick):
            if task.kind == "R":
                continue           # recompute is fused into B by the VJP
            r = task.stage % R
            assert kind[t, r] == NOP, \
                f"tick {t}: rank {r} runs two tasks"
            kind[t, r] = _KIND_OF[task.kind]
            micro[t, r] = task.micro
            chunk[t, r] = task.stage // R

    # --- park buffer: arrival -> consuming F -> (B/Bx and Bw) re-reads ----
    park_iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(R)]
    for i in range(m):
        for s in range(1, n):
            arrive = ix.f[(i, s - 1)] + 1
            last = ix.f[(i, s)] if forward_only else ix.last_b(i, s)
            park_iv[s % R].append((arrive, last, (i, s)))
    p_assign, park_depth, park_high = _alloc_intervals(park_iv)
    for i in range(m):
        for s in range(1, n):
            slot = p_assign[(i, s)]
            park_recv[ix.f[(i, s - 1)] + 1, s % R] = slot
            park_read[ix.f[(i, s)], s % R] = slot
            if not forward_only:
                for tb in ix.b_ticks(i, s):
                    park_read[tb, s % R] = slot

    # --- backward inbox: B(i,s+1)'s cotangent parks until B/Bx (and Bw) ---
    b_depth = 1
    b_high = [0] * R
    if not forward_only:
        b_iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(R)]
        for i in range(m):
            for s in range(n - 1):
                arrive = ix.b[(i, s + 1)] + 1
                b_iv[s % R].append((arrive, ix.last_b(i, s), (i, s)))
        b_assign, b_depth, b_high = _alloc_intervals(b_iv)
        for i in range(m):
            for s in range(n - 1):
                slot = b_assign[(i, s)]
                b_recv[ix.b[(i, s + 1)] + 1, s % R] = slot
                for tb in ix.b_ticks(i, s):
                    b_read[tb, s % R] = slot

    # --- stream stash: every F parks its fresh slice for the backward -----
    fs_depth = 1
    fs_high = [0] * R
    if not forward_only:
        fs_iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(R)]
        for i in range(m):
            for s in range(n):
                fs_iv[s % R].append((ix.f[(i, s)], ix.last_b(i, s), (i, s)))
        fs_assign, fs_depth, fs_high = _alloc_intervals(fs_iv)
        for i in range(m):
            for s in range(n):
                slot = fs_assign[(i, s)]
                fs_slot[ix.f[(i, s)], s % R] = slot
                for tb in ix.b_ticks(i, s):
                    fs_slot[tb, s % R] = slot

    # --- chain send latches (MPMD double buffering): a tick whose output
    # crosses the ring latches it into the depth-1 send register; the
    # executor ships the register at the top of the NEXT tick, overlapping
    # the permute with that tick's compute.  The last global stage never
    # ships forward; stage 0 never ships a cotangent.
    send_slot = np.full((T, R), -1, np.int32)
    b_send_slot = np.full((T, R), -1, np.int32)
    for i in range(m):
        for s in range(n - 1):
            send_slot[ix.f[(i, s)], s % R] = 0
        if not forward_only:
            for s in range(1, n):
                b_send_slot[ix.b[(i, s)], s % R] = 0

    # --- residual stash: BWD_X parks its vjp residuals until BWD_W --------
    resid_write = np.full((T, R), -1, np.int32)
    resid_read = np.full((T, R), -1, np.int32)
    resid_depth = 0
    resid_high = [0] * R
    if residuals == "reuse" and ix.w:
        r_iv: List[List[Tuple[int, int, object]]] = [[] for _ in range(R)]
        for (i, s), tw in ix.w.items():
            tb = ix.b.get((i, s))
            assert tb is not None, f"Bw[{i},{s}] has no matching Bx"
            assert tb < tw, \
                f"Bw[{i},{s}] at tick {tw} must follow its Bx (tick {tb})"
            r_iv[s % R].append((tb, tw, (i, s)))
        r_assign, resid_depth, resid_high = _alloc_intervals(r_iv)
        for (i, s), tw in ix.w.items():
            slot = r_assign[(i, s)]
            resid_write[ix.b[(i, s)], s % R] = slot
            resid_read[tw, s % R] = slot
    else:
        residuals = "recompute"

    # --- stream injection: rank 0's chunk-0 forwards consume + rotate -----
    stream_rot = (kind[:, 0] == FWD) & (chunk[:, 0] == 0)
    for i in range(m):
        stream_slot[ix.f[(i, 0)]] = i // R

    per_stage_stash = tuple(schedules.peak_stash(table, n, ranks=R))
    routes = _lower_routes(ix, T, m, R, skips, portals,
                           has_backward=not forward_only)
    return TaskPlan(kind, micro, chunk, park_recv, park_read, b_recv, b_read,
                    fs_slot, stream_slot, stream_rot, send_slot, b_send_slot,
                    _segments(kind),
                    T, n, R, m, v,
                    park_depth, max(b_depth, 1), max(fs_depth, 1),
                    per_stage_stash, tuple(park_high),
                    per_stage_b_inbox=tuple(b_high),
                    per_stage_fs=tuple(fs_high),
                    has_backward=not forward_only, routes=routes,
                    residuals=residuals, resid_write=resid_write,
                    resid_read=resid_read, resid_depth=resid_depth,
                    per_stage_resid=tuple(resid_high),
                    wire=wire)


def schedule_table(schedule: str, m: int, n: int):
    """Build (but do not lower) the named schedule's task table.

    Returns ``(table, n_stages, ranks)``.  ``"gpipe"``/``"gpipe_fwd"`` map
    to the full GPipe fill/drain table (the clock the legacy autodiff path
    also follows).
    """
    base, v = parse_schedule(schedule)
    if base in ("gpipe", "gpipe_fwd", "gpipe_tasked"):
        return schedules.gpipe_schedule(m, n, checkpoint=False), n, n
    if base == "1f1b":
        return schedules.one_f_one_b_schedule(m, n), n, n
    if base == "interleaved":
        return schedules.interleaved_1f1b_schedule(m, n, v), n * v, n
    if base == "zb":
        return schedules.zb_schedule(m, n), n, n
    raise ValueError(f"unknown schedule {schedule!r}")


def schedule_bubble(schedule: str, m: int, n: int,
                    *, residuals: str = "recompute",
                    remat: str = "dots",
                    executor: str = "spmd",
                    comm_cost: float = 0.0,
                    bwd_comm_cost: Optional[float] = None,
                    route_edges: Sequence[Tuple[int, int]] = (),
                    route_comm_cost: Optional[float] = None) -> float:
    """Dedicated-device bubble fraction of the named schedule's table
    (cost-weighted critical-path idle share) — the dry-run cost model's
    pipeline-efficiency term.  ``residuals`` selects the split-backward
    pricing (``"reuse"`` drops Bw's recompute — unless ``remat="full"``,
    whose stash is empty and still recomputes); ``comm_cost`` prices one
    chain hop and ``executor`` decides whether it overlaps compute
    (``"mpmd"`` double buffering) or serializes after the producing task
    (``"spmd"``).  ``bwd_comm_cost``/``route_comm_cost`` price the
    cotangent chain and skip-route hops separately (byte-derived wire
    terms — the codec can shrink each payload class independently;
    ``None`` = same as ``comm_cost``); ``route_edges`` lists the
    ``(src_stage, dst_stage)`` skip edges whose hops the model should
    charge.  Returns 0 for a single-stage pipeline."""
    if n <= 1:
        return 0.0
    table, n_stages, ranks = schedule_table(schedule, m, n)
    return schedules.device_bubble_fraction(
        table, ranks,
        schedules.default_task_cost(n_stages, ranks, residuals=residuals,
                                    remat=remat),
        comm_cost=comm_cost, overlap_comm=executor == "mpmd",
        bwd_comm_cost=bwd_comm_cost, route_edges=route_edges,
        route_comm_cost=route_comm_cost)


@dataclass(frozen=True)
class PlanCost:
    """Planner-facing time + memory summary of one lowered schedule.

    Times are in stage-forward units under the supplied cost model; slot
    counts are the EXACT per-rank high-water marks of the lowered plan's
    free-list allocator (what the executor allocates), not schedule-level
    bounds.
    """
    t_end: float                      # device-model makespan
    busy: Tuple[float, ...]           # per-rank busy time
    bubble: float                     # 1 - sum(busy) / (ranks * t_end)
    park: Tuple[int, ...]             # per-rank park-slot high-water
    b_inbox: Tuple[int, ...]          # per-rank bwd-inbox high-water
    fs: Tuple[int, ...]               # per-rank stream-stash high-water
    resid: Tuple[int, ...]            # per-rank residual-stash high-water
    n_stages: int
    ranks: int

    def carry_slots(self, rank: int) -> int:
        """Activation-sized buffer slots rank ``rank`` allocates."""
        return int(self.park[rank]) + int(self.b_inbox[rank]) \
            + int(self.fs[rank])


def plan_cost(schedule: str, m: int, n: int, *,
              residuals: str = "recompute", remat: str = "dots",
              executor: str = "spmd", comm_cost: float = 0.0,
              bwd_comm_cost: Optional[float] = None,
              route_edges: Sequence[Tuple[int, int]] = (),
              route_comm_cost: Optional[float] = None,
              stage_weights: Optional[Sequence[float]] = None) -> PlanCost:
    """Score one (schedule, m, n) point: device-model time + exact memory.

    The stable query the automatic planner drives: builds the named
    schedule's task table, prices it with ``stage_weights`` (per-GLOBAL-
    stage forward cost in stage-forward units; ``None`` = the uniform
    ``ranks / n_stages`` share of :func:`schedules.default_task_cost`),
    runs :func:`schedules.simulate_device_times` with the comm/overlap
    terms (``bwd_comm_cost``/``route_edges``/``route_comm_cost`` price
    the cotangent chain and skip-route wire hops; see
    :func:`schedule_bubble`), and lowers the table once to read the
    executor's true per-rank buffer high-water marks.
    """
    table, n_stages, ranks = schedule_table(schedule, m, n)
    if stage_weights is None:
        cost_of = schedules.default_task_cost(
            n_stages, ranks, residuals=residuals, remat=remat)
    else:
        if len(stage_weights) != n_stages:
            raise ValueError(f"stage_weights has {len(stage_weights)} "
                             f"entries for {n_stages} stages")
        cost_of = schedules.weighted_task_cost(
            stage_weights, residuals=residuals, remat=remat)
    t_end, busy = schedules.simulate_device_times(
        table, ranks, cost_of, comm_cost=comm_cost,
        overlap_comm=executor == "mpmd",
        bwd_comm_cost=bwd_comm_cost, route_edges=route_edges,
        route_comm_cost=route_comm_cost)
    tplan = plan_for(schedule, m, n, residuals=residuals)
    bubble = 1.0 - sum(busy) / (ranks * t_end) if t_end > 0 else 0.0

    def per_rank(values, fallback):
        if len(values) == ranks:
            return tuple(int(x) for x in values)
        return tuple(int(fallback) for _ in range(ranks))

    return PlanCost(
        t_end=float(t_end), busy=tuple(float(b) for b in busy),
        bubble=float(bubble),
        park=per_rank(tplan.per_stage_park, tplan.park_depth),
        b_inbox=per_rank(tplan.per_stage_b_inbox, tplan.b_inbox_depth),
        fs=per_rank(tplan.per_stage_fs, tplan.fs_depth),
        resid=per_rank(tplan.per_stage_resid, tplan.resid_depth),
        n_stages=n_stages, ranks=ranks)


def plan_for(schedule: str, m: int, n: int, *,
             skips: Sequence[SkipSpec] = (),
             portals: bool = True,
             residuals: str = "recompute",
             wire: Optional[WireSpec] = None) -> TaskPlan:
    """Build + lower the named schedule for ``n`` pipe ranks.

    ``"gpipe"``/``"gpipe_tasked"``, ``"1f1b"``, ``"interleaved:v"`` and
    ``"zb"`` produce full F+B plans for the fused executor;
    ``"gpipe_fwd"`` produces the forward-only clock-cycle plan (paper
    Algorithm 1) that inference and the autodiff-backward path execute.
    ``residuals="reuse"`` adds the Bx->Bw residual-stash events to
    split-backward plans (``"zb"``); ``wire`` selects the on-the-wire
    codec (default fp32 identity).
    """
    if parse_schedule(schedule)[0] == "gpipe_fwd":
        table = [list(tick) for tick in schedules.clock_cycles(m, n)]
        return lower_tasks(table, m, n, skips=skips, portals=portals,
                           forward_only=True, wire=wire)
    table, n_stages, ranks = schedule_table(schedule, m, n)
    return lower_tasks(table, m, n_stages, ranks=ranks, skips=skips,
                       portals=portals, residuals=residuals, wire=wire)


def assert_route_overlap(tplan: TaskPlan) -> int:
    """Plan-level tripwire: no route hop serializes after its producer.

    For every route arrival (value and cotangent) there must be a latch —
    a non--1 ``send`` entry — one tick EARLIER on the rank the arrival's
    permute sources from (the rank itself for same-rank identity holds).
    That is exactly the property the mpmd executor's double buffering
    relies on to ship route payloads at the top of the arrival tick,
    overlapped with that tick's compute.  Returns the number of arrivals
    checked; raises ``AssertionError`` with the offending (route, tick,
    rank) on violation.
    """
    checked = 0
    for rt in tplan.routes:
        for tag, arrs, sends, perm in (("value", rt.recv, rt.send,
                                        rt.fwd_perm),
                                       ("cotangent", rt.g_recv, rt.g_send,
                                        rt.bwd_perm)):
            src_of = {d: s for s, d in perm}
            for t, r in zip(*np.nonzero(arrs >= 0)):
                t, r = int(t), int(r)
                assert t >= 1, \
                    (f"route {rt.key} {tag} arrival at tick 0 on rank {r} "
                     f"has no earlier latch tick")
                src = src_of.get(r, r)
                assert sends[t - 1, src] != -1, \
                    (f"route {rt.key} {tag} arrival at tick {t} rank {r} "
                     f"has no latch at tick {t - 1} on source rank {src} — "
                     f"the hop would serialize after its producer")
                checked += 1
    return checked
