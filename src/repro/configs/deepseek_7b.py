"""deepseek-7b [dense]: llama-arch, MHA. [arXiv:2401.02954; hf]"""
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig

ARCH = ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, d_ff=11008, vocab=102400,
    attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=128),
    act="silu", norm="rms",
    source="arXiv:2401.02954; hf",
)

# pipe 16 x tp 1: 30 -> 2/stage with 2 identity-pad layers.
PARALLEL = ParallelConfig(pipe=16, tp=1)

# §Perf-hillclimbed variant (EXPERIMENTS.md §4-A): ZeRO-1-style per-step
# weight gathering + pipe-sharded input streaming; roofline 0.156 -> 0.240.
PARALLEL_OPTIMIZED = PARALLEL.with_(gather_weights_once=True,
                                    stream_inputs=True)
