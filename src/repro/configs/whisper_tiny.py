"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig

ARCH = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, enc_layers=4,              # 4 decoder + 4 encoder blocks
    d_model=384, d_ff=1536, vocab=51865,
    attn=AttentionConfig(n_heads=6, n_kv_heads=6, head_dim=64,
                         use_rope=False),  # whisper: abs. positions
    act="gelu", norm="ln", frontend="audio_stub",
    source="arXiv:2212.04356; unverified",
)

# model axis 16 = pipe 8 x tp 2: 1 block/stage, no padding; encoder output
# reaches decoder stages via portals.
PARALLEL = ParallelConfig(pipe=8, tp=2)

# §Perf-hillclimbed variant (EXPERIMENTS.md §4-B): surplus model-axis
# capacity folded into extra data parallelism; roofline 0.007 -> 0.068.
PARALLEL_OPTIMIZED = PARALLEL.with_(dp2=4, pipe=2, tp=2,
                                    gather_weights_once=True,
                                    stream_inputs=True)
