"""smollm-360m [dense]: llama-arch small. [hf:HuggingFaceTB/SmolLM-360M; hf]"""
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig

ARCH = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, d_ff=2560, vocab=49152,
    attn=AttentionConfig(n_heads=15, n_kv_heads=5, head_dim=64),
    act="silu", norm="rms", tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M; hf",
)

# 15 heads are indivisible by any tp in {2,4,8,16} -> pipe 16 x tp 1.
PARALLEL = ParallelConfig(pipe=16, tp=1)
