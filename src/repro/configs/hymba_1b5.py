"""hymba-1.5b [hybrid]: parallel attention + mamba heads, 3 global-attention
layers + SWA elsewhere, ssm_state=16. [arXiv:2411.13676; hf]"""
from repro.configs.base import (ArchConfig, AttentionConfig, ParallelConfig,
                                SSMConfig)

ARCH = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, d_ff=5504, vocab=32001,
    attn=AttentionConfig(n_heads=25, n_kv_heads=5, head_dim=64,
                         kind="swa", window=1024,
                         global_layers=(0, 15, 31)),
    ssm=SSMConfig(state_dim=16, head_dim=64),
    act="silu", norm="rms",
    source="arXiv:2411.13676; hf",
)

# 25 heads indivisible -> pipe 16 x tp 1: 2 layers/stage, no padding.
PARALLEL = ParallelConfig(pipe=16, tp=1)
