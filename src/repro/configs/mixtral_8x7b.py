"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, ParallelConfig

ARCH = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, d_ff=14336, vocab=32000,
    attn=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                         kind="swa", window=4096),
    moe=MoEConfig(n_experts=8, top_k=2),
    act="silu", norm="rms",
    source="arXiv:2401.04088; hf",
)

# pipe 8 x tp 2: 4 layers/stage; experts EP-sharded over tp (4/shard).
# SWA => bounded window cache => long_500k decode applies.
PARALLEL = ParallelConfig(pipe=8, tp=2)
