"""gemma-2b [dense]: GeGLU, head_dim=256, MQA. [arXiv:2403.08295; hf]"""
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig

ARCH = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, d_ff=16384, vocab=256000,
    attn=AttentionConfig(n_heads=8, n_kv_heads=1, head_dim=256),
    act="geglu", norm="rms", tie_embeddings=True,
    source="arXiv:2403.08295; hf",
)

# 18 layers: pipe 2 x tp 8 gives 9 layers/stage with zero padding; MQA kv
# head replicates under tp.
PARALLEL = ParallelConfig(pipe=2, tp=8)
