"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as an :class:`ArchConfig`; every
assigned input shape as a :class:`ShapeConfig`; and the distribution layout
(how the production mesh's ``model`` axis factors into ``pipe × tp``, how many
micro-batches the GPipe schedule uses, which remat policy applies, ...) as a
:class:`ParallelConfig`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

from repro.core.wire import WireSpec


# ---------------------------------------------------------------------------
# Attention / MoE / SSM sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    kind: str = "full"            # "full" | "swa" (sliding window) | "none"
    window: int = 0               # sliding-window size when kind == "swa"
    causal: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True         # whisper uses learned abs. positions instead
    # hymba-style mixed layouts: indices of layers that use *full* attention
    # while the rest use SWA (empty = uniform `kind`).
    global_layers: Tuple[int, ...] = ()


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM head-group (used by rwkv6/hymba families)."""
    state_dim: int = 16
    n_heads: int = 0              # 0 = derive from d_model / head_dim
    head_dim: int = 64
    conv_dim: int = 4


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | conv
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    act: str = "silu"             # silu (SwiGLU) | geglu | gelu
    norm: str = "rms"             # rms | ln
    tie_embeddings: bool = False
    # encoder-decoder extras (whisper): ``n_layers`` counts *decoder* layers.
    enc_layers: int = 0
    enc_len: int = 0              # fixed encoder sequence length (audio frames)
    # modality frontend stub: number of patch/frame embeddings prepended
    frontend: str = "none"        # none | audio_stub | vision_stub
    param_dtype: str = "bfloat16"
    # documentation pointer (public source tier)
    source: str = ""

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def layer_params(self) -> int:
        """Approximate per-block parameter count (for balance / MODEL_FLOPS)."""
        d, f = self.d_model, self.d_ff
        n = 0
        if self.attn is not None and self.attn.kind != "none":
            a = self.attn
            n += d * a.n_heads * a.head_dim * 2              # q, o
            n += d * a.n_kv_heads * a.head_dim * 2           # k, v
        if self.moe is not None:
            n += self.moe.n_experts * 3 * d * f              # gate/up/down per expert
            n += d * self.moe.n_experts                      # router
        elif self.family in ("ssm",):
            # rwkv6: time-mix (r,k,v,w,g,o ~ 6 d^2 at head granularity) + channel-mix
            n += 6 * d * d + 2 * d * f
        elif self.family == "hybrid":
            n += 3 * d * d                                   # ssm in/out/dt projections
            n += 3 * d * f
        else:
            mults = 3 if self.act in ("silu", "geglu") else 2
            n += mults * d * f
        return n

    def total_params(self) -> int:
        n = (self.n_layers + self.enc_layers) * self.layer_params()
        n += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return n

    def active_params_per_token(self) -> int:
        """For MoE: params touched per token (6*N_active*D convention)."""
        per_block = self.layer_params()
        if self.moe is not None:
            dense = per_block - self.moe.n_experts * 3 * self.d_model * self.d_ff
            active = dense + self.moe.top_k * 3 * self.d_model * self.d_ff
            per_block = active
        n = (self.n_layers + self.enc_layers) * per_block
        n += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return n


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Parallel / schedule config
# ---------------------------------------------------------------------------

#: canonical rematerialization policies (mirrored as
#: ``repro.core.checkpointing.POLICIES`` — defined here so the config layer
#: can validate at parse time without importing jax).
REMAT_POLICIES = ("none", "full", "dots", "dots_no_batch")

#: split-backward residual handling (ZB-H1): ``"recompute"`` re-runs the
#: stage forward inside both Bx and Bw; ``"reuse"`` stashes the residuals Bx
#: materialized and re-reads them at Bw (no second remat).
RESIDUAL_MODES = ("recompute", "reuse")

#: executor lowering of the task plan: ``"spmd"`` runs one rank-uniform
#: program (every rank traces every segment branch, buffers at ring-max
#: depth — the reference path); ``"mpmd"`` specializes a program per rank
#: (``plan.specialize``): each rank's column drives its own pruned branch
#: set under a top-level rank-indexed switch, with the chain ``ppermute``
#: double-buffered one tick ahead so comm overlaps the next stage compute.
EXECUTORS = ("spmd", "mpmd")


#: schedule bases the config layer accepts ("interleaved" carries a
#: ``virtual_stages`` count; every other base has exactly one chunk/rank).
SCHEDULE_BASES = ("gpipe", "gpipe_fwd", "gpipe_tasked", "1f1b",
                  "interleaved", "zb")


@dataclass(frozen=True)
class ScheduleSpec:
    """Structured schedule selection — the planner-facing replacement for
    overloaded ``schedule="interleaved:2"`` strings.

    Bundles the four knobs that together decide what the tick loop runs:
    the schedule *base* (task-table family), the interleaving factor
    ``virtual_stages`` (only meaningful for ``base="interleaved"``), the
    split-backward ``residuals`` mode, and the ``executor`` lowering.
    ``to_dict``/``from_dict`` round-trip exactly (the planner's
    ``PlanReport`` serializes specs through them), and :meth:`name`
    renders the legacy string form the rest of the stack still accepts.
    """
    base: str = "gpipe"
    virtual_stages: int = 1
    residuals: str = "recompute"
    executor: str = "spmd"

    def __post_init__(self):
        if self.base not in SCHEDULE_BASES:
            raise ValueError(f"unknown schedule base {self.base!r}; "
                             f"want one of {SCHEDULE_BASES}")
        if self.virtual_stages < 1:
            raise ValueError(
                f"virtual stages must be >= 1, got {self.virtual_stages}")
        if self.base != "interleaved" and self.virtual_stages != 1:
            raise ValueError(
                f"schedule base {self.base!r} has exactly 1 virtual stage "
                f"per rank, got {self.virtual_stages}")
        if self.residuals not in RESIDUAL_MODES:
            raise ValueError(f"unknown residuals mode {self.residuals!r}; "
                             f"want one of {RESIDUAL_MODES}")
        if self.executor not in EXECUTORS:
            raise ValueError(f"unknown executor {self.executor!r}; "
                             f"want one of {EXECUTORS}")

    @property
    def name(self) -> str:
        """The legacy string form (``"interleaved:3"``, ``"zb"``, ...)."""
        if self.base == "interleaved":
            return f"interleaved:{self.virtual_stages}"
        return self.base

    @classmethod
    def from_string(cls, schedule: str, *, residuals: str = "recompute",
                    executor: str = "spmd") -> "ScheduleSpec":
        """Build a spec from a legacy ``"interleaved:2"``-style string."""
        if schedule == "interleaved" or schedule.startswith("interleaved:"):
            v = int(schedule.split(":", 1)[1]) if ":" in schedule else 2
            return cls("interleaved", v, residuals, executor)
        return cls(schedule, 1, residuals, executor)

    def to_dict(self) -> dict:
        return {"base": self.base, "virtual_stages": self.virtual_stages,
                "residuals": self.residuals, "executor": self.executor}

    @classmethod
    def from_dict(cls, d: dict) -> "ScheduleSpec":
        return cls(base=d["base"],
                   virtual_stages=int(d.get("virtual_stages", 1)),
                   residuals=d.get("residuals", "recompute"),
                   executor=d.get("executor", "spmd"))


@dataclass(frozen=True)
class PlanSpec:
    """A complete, serializable pipeline plan: schedule spec + stage
    partition + microbatch count.

    This is the planner's unit of search and the payload of every
    ``PlanReport`` entry: :meth:`apply_to` turns it into a concrete
    :class:`ParallelConfig` (which is how ``dryrun`` and
    ``steps.build_train_step`` consume a planner choice), and
    ``to_dict``/``from_dict`` round-trip bit-for-bit through JSON.
    ``partition`` is the per-GLOBAL-stage layer counts (length
    ``pipe * virtual_stages``, summing to the model's layer count);
    empty means the legacy uniform ceil layout.
    """
    schedule: ScheduleSpec
    pipe: int
    microbatches: int
    partition: Tuple[int, ...] = ()
    wire: str = "fp32"            # on-the-wire codec (WireSpec.parse form)

    def __post_init__(self):
        object.__setattr__(self, "partition", tuple(self.partition))
        WireSpec.parse(self.wire)         # rejects malformed wire specs
        if self.pipe < 1:
            raise ValueError(f"need pipe >= 1, got {self.pipe}")
        if self.microbatches < 1:
            raise ValueError(f"need microbatches >= 1, "
                             f"got {self.microbatches}")
        if self.partition:
            n_stages = self.pipe * self.schedule.virtual_stages
            if len(self.partition) != n_stages:
                raise ValueError(
                    f"partition has {len(self.partition)} entries for "
                    f"{n_stages} global stages")
            if any(int(p) < 0 for p in self.partition):
                raise ValueError(f"negative partition entry: "
                                 f"{self.partition}")

    def to_dict(self) -> dict:
        return {"schedule": self.schedule.to_dict(), "pipe": self.pipe,
                "microbatches": self.microbatches,
                "partition": list(self.partition),
                "wire": self.wire}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanSpec":
        return cls(schedule=ScheduleSpec.from_dict(d["schedule"]),
                   pipe=int(d["pipe"]),
                   microbatches=int(d["microbatches"]),
                   partition=tuple(int(p) for p in d.get("partition", ())),
                   wire=d.get("wire", "fp32"))

    def apply_to(self, pcfg: "ParallelConfig") -> "ParallelConfig":
        """Project this plan onto a base config (keeps tp/data/remat/...)."""
        return pcfg.with_(pipe=self.pipe, n_micro=self.microbatches,
                          schedule=self.schedule.name,
                          residuals=self.schedule.residuals,
                          executor=self.schedule.executor,
                          partition=self.partition,
                          wire=self.wire)


def parse_schedule(schedule: str) -> Tuple[str, int]:
    """DEPRECATED shim: split a schedule string into (base, virtual_stages).

    New code should use :meth:`ScheduleSpec.from_string` (this shim merely
    constructs the spec and unpacks it, so the two can never disagree).
    Kept because the string form is pervasive in configs and CLIs:
    ``"interleaved:3"`` -> ``("interleaved", 3)`` (bare ``"interleaved"``
    defaults to 2 chunks); every other name has one virtual stage per rank.
    """
    spec = ScheduleSpec.from_string(schedule)
    return spec.base, spec.virtual_stages


@dataclass(frozen=True)
class ParallelConfig:
    """How the production mesh maps onto this architecture.

    The assignment's production grid is ``(data=16, model=16)`` per pod; the
    ``model`` axis factors into ``pipe × tp`` (``pipe * tp == 16``).
    """
    pipe: int = 16
    tp: int = 1
    data: int = 16
    pod: int = 1
    n_micro: int = 8
    microbatch: int = 0           # 0 = derive from global_batch
    dp2: int = 1                  # surplus model-axis folded into extra DP
    schedule: str = "gpipe"       # execution order of the tick loop:
    #   "gpipe"         — fill/drain forward, autodiff-induced reverse
    #                     clock-cycle backward (paper Algorithm 1);
    #   "gpipe_tasked"  — the same task table, but executed by the fused
    #                     scheduler (explicit-VJP backwards in the loop);
    #   "1f1b"          — PipeDream-flush: same synchronous semantics, each
    #                     stage drains backwards early, bounding stashed
    #                     activations at min(n - j, m) instead of m;
    #   "interleaved:v" — Megatron-style interleaved 1F1B with v virtual
    #                     stages per rank (bubble shrinks ~1/v; needs
    #                     n_micro % pipe == 0);
    #   "zb"            — ZB-H1-style split backward: Bx (input cotangent)
    #                     on the critical path, Bw (weight grad) filling
    #                     bubble ticks.
    grad_reduce: str = "ordered"  # fused-scheduler cotangent folding:
    #   "ordered" — per-micro slots + fixed-order sum: gradients are
    #               bitwise-identical across schedules (costs m x stage-
    #               param memory for the slots);
    #   "running" — fold in schedule order: O(1) memory, bit-exact only
    #               against itself.
    remat: str = "full"           # none | full | dots | dots_no_batch
    #   (checkpointing.POLICIES): what each stage saves for its backward.
    #   "full" stores only the stage boundary input (the paper's §3.2.4
    #   setting); "dots" / "dots_no_batch" store matmul outputs; "none"
    #   stores whatever the vjp naturally needs.  Under residuals="reuse"
    #   the policy also decides WHAT Bx stashes for Bw (see ``residuals``).
    residuals: str = "recompute"  # split-backward (zb) residual handling:
    #   "recompute" — Bx and Bw each rematerialize the stage forward from
    #               the parked boundary input (2 forwards of remat per
    #               micro — the ZB tradeoff PR 3 priced);
    #   "reuse"   — true ZB-H1: Bx stashes the vjp residuals its remat
    #               materialized (filtered by the remat policy) into a
    #               plan-allocated residual stash, and Bw re-reads them
    #               instead of re-running the forward (Bw ~ 1 forward of
    #               work instead of 2).  No effect on fused-B schedules.
    executor: str = "spmd"        # task-plan lowering target (EXECUTORS):
    #   "spmd" — one rank-uniform program: every segment traces the UNION
    #            of all ranks' branches and buffers flatten to the ring-max
    #            depth (the reference path);
    #   "mpmd" — per-rank specialized programs (plan.specialize): a
    #            top-level rank-indexed switch dispatches each rank's own
    #            pruned branch set / slot columns, and the chain ppermute
    #            is double-buffered one tick ahead (tick t's boundary
    #            output ships while tick t+1's compute runs).  Bitwise-
    #            identical to "spmd" by construction.
    remat_layers: bool = False    # nested checkpointing: remat each layer
    #   inside the stage as well, so a backward tick stashes only bf16
    #   layer-boundary activations instead of every layer's fp32 internals
    #   (the memory lever for deep stages, e.g. llama3's 32 layers/stage).
    gather_weights_once: bool = False  # pre-gather FSDP stage weights per
    #   step (ZeRO-1-style comm) instead of re-gathering every clock tick
    #   (ZeRO-3).  Trades +unsharded-stage-weights memory for ~T x fewer
    #   all-gather bytes; the dominant lever for collective-bound cells.
    remat_last_micro: bool = False  # paper §2.1: skip F'_{m,j} (unrolled only)
    unroll_ticks: bool = False
    overlap: bool = True          # async send-before-compute (paper C3 analogue)
    portals: bool = True          # paper C4
    stream_inputs: bool = False   # beyond-paper: shard µbatches over pipe + rotate
    fsdp: bool = True             # ZeRO-3 over the data axis
    grad_compression: str = "none"  # none | int8_ef (cross-pod): blockwise
    #   int8 + error feedback on the data-parallel gradient reduce
    #   (runtime.compression.EFCompressor; EF residual rides OptState.ef).
    wire: str = "fp32"            # pipeline on-the-wire codec, WireSpec.parse
    #   form: "fp32" | "bf16" | "int8-ef" uniform, or per payload class
    #   "chain=bf16,portal=fp32,cotangent=int8-ef".  fp32 is bitwise
    #   lossless; bf16 halves wire bytes (exact on bf16-cast models);
    #   int8-ef quantizes with per-(rank, stream) error feedback.
    activation_dtype: str = "bfloat16"
    partition: Tuple[int, ...] = ()  # per-GLOBAL-stage layer counts (length
    #   pipe * virtual_stages, summing to the model's layer count) — the
    #   torchgpipe.balance output wired through core.stage.partition_layout.
    #   Empty = the legacy uniform ceil layout with tail padding.

    def __post_init__(self):
        # Validate knob values at parse time: a typo'd policy should fail
        # when the config is built, not ticks deep inside wrap_stage / the
        # fused executor's backward branches.
        if self.remat not in REMAT_POLICIES:
            raise ValueError(f"unknown remat policy {self.remat!r}; "
                             f"want one of {REMAT_POLICIES}")
        if self.residuals not in RESIDUAL_MODES:
            raise ValueError(f"unknown residuals mode {self.residuals!r}; "
                             f"want one of {RESIDUAL_MODES}")
        if self.executor not in EXECUTORS:
            raise ValueError(f"unknown executor {self.executor!r}; "
                             f"want one of {EXECUTORS}")
        if self.grad_compression not in ("none", "int8_ef"):
            raise ValueError(
                f"unknown grad_compression {self.grad_compression!r}; "
                f"want 'none' or 'int8_ef'")
        WireSpec.parse(self.wire)                 # rejects malformed specs
        base, v = parse_schedule(self.schedule)   # rejects malformed specs
        object.__setattr__(self, "partition", tuple(self.partition))
        if self.partition:
            if len(self.partition) != self.pipe * v:
                raise ValueError(
                    f"partition has {len(self.partition)} entries for "
                    f"{self.pipe * v} global stages (pipe={self.pipe}, "
                    f"virtual_stages={v})")
            if any(int(p) < 0 for p in self.partition):
                raise ValueError(f"negative partition entry: "
                                 f"{self.partition}")

    def advisories(self) -> Tuple[str, ...]:
        """Config smells worth surfacing before a run (dryrun prints these).

        ``zb`` + ``residuals="recompute"`` prices Bx+Bw at 4 stage-forwards
        of work per micro vs the fused B's 3, so in low-bubble regimes
        (small pipe, large n_micro) the split backward does MORE total work
        than 1F1B saves — the device model shows it losing at pipe=2.
        ``residuals="reuse"`` drops Bw's recompute and restores the ZB win.
        """
        out = []
        if parse_schedule(self.schedule)[0] == "zb" \
                and self.residuals == "recompute":
            out.append(
                "schedule='zb' with residuals='recompute' pays 2 remat "
                "forwards per micro (Bx+Bw = 4F vs fused B = 3F) and can be "
                "SLOWER than 1f1b in low-bubble regimes; set "
                "residuals='reuse' (true ZB-H1) to drop Bw's recompute.")
        return tuple(out)

    def with_(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def model_axis(self) -> int:
        return self.pipe * self.tp * self.dp2

    @property
    def schedule_spec(self) -> ScheduleSpec:
        """This config's schedule knobs as a structured spec."""
        return ScheduleSpec.from_string(self.schedule,
                                        residuals=self.residuals,
                                        executor=self.executor)

    @property
    def spec(self) -> PlanSpec:
        """This config's pipeline plan as a first-class, serializable
        :class:`PlanSpec` (schedule + partition + microbatches) — the
        object the planner searches over and ``PlanReport`` serializes."""
        return PlanSpec(schedule=self.schedule_spec, pipe=self.pipe,
                        microbatches=self.n_micro,
                        partition=self.partition, wire=self.wire)

    @property
    def wire_spec(self) -> WireSpec:
        """This config's on-the-wire codec selection, parsed."""
        return WireSpec.parse(self.wire)

    @property
    def schedule_base(self) -> str:
        return parse_schedule(self.schedule)[0]

    @property
    def virtual_stages(self) -> int:
        """Chunks per rank: the model is cut into pipe * virtual_stages
        global stages (1 for every non-interleaved schedule)."""
        return parse_schedule(self.schedule)[1]

    @classmethod
    def auto(cls, arch, shape, hardware=None, executors=("spmd", "mpmd"),
             **overrides) -> "ParallelConfig":
        """Single planner entrypoint: search the plan space for ``arch`` ×
        ``shape`` on ``hardware`` and return a concrete config.

        ``hardware`` is a :class:`repro.planner.hardware.HardwareSpec`, a
        path to a ``hardware.yaml``, or ``None`` (spec defaults).
        ``overrides`` seed the base config the plan is projected onto
        (``data=2``, ``remat="dots"``, ...) — the planner owns ``pipe``,
        ``n_micro``, ``schedule``, ``residuals``, ``executor``, and
        ``partition``; everything else passes through.  ``executors``
        restricts the executor leg of the search (``("spmd",)`` where
        per-rank specialized compilation isn't worth it, e.g. host-CPU
        emulation).  Replaces the
        manual five-knob dance: the chosen partition/schedule/executor
        come ranked from the calibrated device model under the
        hardware's memory budget.
        """
        from repro.planner import plan_arch
        from repro.planner.hardware import HardwareSpec
        if hardware is None:
            hardware = HardwareSpec()
        elif not isinstance(hardware, HardwareSpec):
            hardware = HardwareSpec.from_yaml(hardware)
        base = cls(pipe=hardware.ranks, tp=1, data=1, pod=1,
                   n_micro=1).with_(**overrides)
        report = plan_arch(arch, shape, hardware, base=base,
                           executors=executors)
        best = report.best
        if best is None:
            raise ValueError(
                f"planner found no feasible plan for {arch.name}/"
                f"{shape.name} within {hardware.memory_bytes / 2**30:.1f} "
                f"GiB/rank — see report.candidates for the closest misses")
        return best.spec.apply_to(base)

    @classmethod
    def plan(cls, arch, shape, hardware=None, **overrides
             ) -> "ParallelConfig":
        """Alias for :meth:`auto`."""
        return cls.auto(arch, shape, hardware, **overrides)


# ---------------------------------------------------------------------------
# Roofline hardware constants (TPU v5e per assignment)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareConstants:
    peak_flops_bf16: float = 197e12      # per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link
    hbm_bytes: float = 16 * 1024 ** 3    # v5e HBM capacity


V5E = HardwareConstants()


# ---------------------------------------------------------------------------
# A full experiment cell = arch × shape × parallel
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    parallel: ParallelConfig

    @property
    def key(self) -> str:
        return f"{self.arch.name}/{self.shape.name}"
