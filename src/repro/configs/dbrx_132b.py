"""dbrx-132b [moe]: 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, ParallelConfig

ARCH = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, d_ff=10752, vocab=100352,
    attn=AttentionConfig(n_heads=48, n_kv_heads=8, head_dim=128),
    moe=MoEConfig(n_experts=16, top_k=4),
    act="silu", norm="rms",
    source="hf:databricks/dbrx-base; unverified",
)

# pipe 8 x tp 2: 5 layers/stage; experts EP-sharded over tp (8/shard).
PARALLEL = ParallelConfig(pipe=8, tp=2)
