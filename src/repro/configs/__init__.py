"""Architecture registry: full assigned configs + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (ALL_SHAPES, SHAPES_BY_NAME, ArchConfig, Cell,
                                ParallelConfig, ShapeConfig)

_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "smollm-360m": "smollm_360m",
    "gemma-2b": "gemma_2b",
    "llama3-405b": "llama3_405b",
    "deepseek-7b": "deepseek_7b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "dbrx-132b": "dbrx_132b",
    "mixtral-8x7b": "mixtral_8x7b",
    "pixtral-12b": "pixtral_12b",
    "hymba-1.5b": "hymba_1b5",
}

ARCH_NAMES: List[str] = list(_MODULES)

# long_500k requires sub-quadratic decode state: SSM (rwkv6), hybrid
# SSM+SWA (hymba), or uniform SWA (mixtral).  Pure full-attention archs are
# skipped per assignment (DESIGN.md §3/§4).
SUBQUADRATIC = {"rwkv6-1.6b", "hymba-1.5b", "mixtral-8x7b"}


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.ARCH


def get_parallel(name: str, *, optimized: bool = False) -> ParallelConfig:
    """Arch's production layout; ``optimized=True`` selects the §Perf-
    hillclimbed variant where one exists (EXPERIMENTS.md §4)."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if optimized and hasattr(mod, "PARALLEL_OPTIMIZED"):
        return mod.PARALLEL_OPTIMIZED
    return mod.PARALLEL


def shape_applies(arch: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return arch.name in SUBQUADRATIC
    return True


def derive_n_micro(shape: ShapeConfig, pcfg: ParallelConfig,
                   target_ratio: int = 4) -> int:
    """Largest m with: B % m == 0, (B/m) % dp == 0, m <= target_ratio*pipe.

    GPipe wants m >> n for small bubbles; the global micro-batch must still
    shard over the (pod, data) axes.
    """
    dp = pcfg.data * pcfg.pod * pcfg.dp2
    B = shape.global_batch
    best = 1
    for m in range(1, min(B, target_ratio * pcfg.pipe) + 1):
        if B % m == 0 and (B // m) % dp == 0:
            best = m
    return best


def cells_for(name: str, *, multi_pod: bool = False) -> List[Cell]:
    arch = get_arch(name)
    pcfg = get_parallel(name)
    pcfg = pcfg.with_(pod=2 if multi_pod else 1)
    out = []
    for shape in ALL_SHAPES:
        if not shape_applies(arch, shape):
            continue
        m = derive_n_micro(shape, pcfg)
        out.append(Cell(arch, shape, pcfg.with_(n_micro=m)))
    return out


def all_cells(*, multi_pod: bool = False) -> List[Cell]:
    return [c for n in ARCH_NAMES for c in cells_for(n, multi_pod=multi_pod)]


# ---------------------------------------------------------------------------
# Reduced smoke configs: same family/topology, tiny dims — run on 1 CPU dev.
# ---------------------------------------------------------------------------

def smoke_arch(name: str) -> ArchConfig:
    a = get_arch(name)
    kw = dict(
        n_layers=min(a.n_layers, 4), d_model=64, d_ff=128, vocab=256,
        enc_layers=min(a.enc_layers, 2) if a.enc_layers else 0,
    )
    if a.attn is not None:
        heads = 4 if a.attn.n_heads % 2 == 0 else 3
        kv = max(1, heads // 2) if a.attn.n_kv_heads < a.attn.n_heads else heads
        gl = tuple(g for g in ((0, 2) if a.attn.global_layers else ())
                   if g < kw["n_layers"])
        kw["attn"] = dataclasses.replace(
            a.attn, n_heads=heads, n_kv_heads=kv, head_dim=16,
            window=min(a.attn.window, 8) if a.attn.window else 0,
            global_layers=gl)
    if a.moe is not None:
        # capacity_factor high enough that no token is ever dropped: capacity
        # dropping depends on the dispatch-group size, which micro-batching
        # changes (the MoE analogue of the paper's §2 BatchNorm caveat) — the
        # equivalence tests need routing to be exact.
        kw["moe"] = dataclasses.replace(a.moe, n_experts=4, top_k=2,
                                        capacity_factor=8.0)
    if a.ssm is not None:
        kw["ssm"] = dataclasses.replace(a.ssm, head_dim=16, state_dim=4)
    return dataclasses.replace(a, **kw)


def smoke_parallel(name: str) -> ParallelConfig:
    return ParallelConfig(pipe=1, tp=1, data=1, pod=1, n_micro=2)
