"""pixtral-12b [vlm]: pixtral-ViT + mistral-nemo decoder; vision frontend
stubbed (precomputed patch embeddings). [hf:mistralai/Pixtral-12B-2409;
unverified]"""
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig

ARCH = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, d_ff=14336, vocab=131072,
    attn=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                         rope_theta=1000000.0),
    act="silu", norm="rms", frontend="vision_stub",
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

# pipe 8 x tp 2: 5 layers/stage, no padding.
PARALLEL = ParallelConfig(pipe=8, tp=2)
