"""llama3-405b [dense]: GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig

ARCH = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, d_ff=53248, vocab=128256,
    attn=AttentionConfig(n_heads=128, n_kv_heads=8, head_dim=128,
                         rope_theta=500000.0),
    act="silu", norm="rms",
    source="arXiv:2407.21783; unverified",
)

# pipe 4 x tp 4: 126 -> 32/stage with 2 identity-pad layers (1.6% FLOPs).
PARALLEL = ParallelConfig(pipe=4, tp=4)

# §Perf-hillclimbed variant (EXPERIMENTS.md §4-C): nested per-layer remat
# (-49% memory/device) + input streaming.
PARALLEL_OPTIMIZED = PARALLEL.with_(remat_layers=True, stream_inputs=True)
