"""rwkv6-1.6b [ssm]: Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchConfig, ParallelConfig

ARCH = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, d_ff=7168, vocab=65536,
    attn=None, act="silu", norm="ln",
    source="arXiv:2404.05892; unverified",
)

# pipe 8 x tp 2: 3 layers/stage, no padding; tp shards channel dims.
PARALLEL = ParallelConfig(pipe=8, tp=2)
