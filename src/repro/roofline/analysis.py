"""Roofline analysis from compiled dry-run artifacts (assignment §Roofline).

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = Σ collective_link_bytes_per_device / link_bw

(cost_analysis()/memory_analysis() are *per-device* under SPMD — verified in
this environment; DESIGN.md §8.)

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip count
(verified empirically), and the dry-run keeps the clock loop and the layer
loop as scans for compile speed.  This module therefore implements its own
trip-count-aware cost walk over the compiled HLO text:

  * while-loop trip counts are recovered from each loop's condition
    computation (jax emits scans as `compare(iter, constant(T))`);
  * a call graph (while bodies, fusions, calls, reduces, conditionals) gives
    every computation a multiplier = product of enclosing trip counts;
  * FLOPs  = Σ over `dot`/`convolution` ops of 2·|result|·K, multiplied out
    (elementwise FLOPs are ignored — MXU dots dominate every assigned arch);
  * HBM bytes = Σ over `dot`/`convolution` ops of (lhs + rhs + out) bytes,
    multiplied by trip counts, plus collective buffers.  On TPU the MXU's
    operand streams dominate HBM traffic and elementwise chains fuse into
    them; this model prices exactly the weight re-streaming per tick that
    the pipeline schedule implies (weights are while-loop operands read on
    every clock cycle) while ignoring fused elementwise traffic (documented
    underestimate of O(10-20%));
  * collective link bytes use ring factors: all-gather/reduce-scatter/
    all-to-all (g-1)/g, all-reduce 2(g-1)/g, collective-permute 1 hop.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.configs.base import HardwareConstants, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "call", "after-all",
                 "iota", "copy-start", "copy-done"}


def shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        total += math.prod(dims) * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)   # %name -> shape


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)")
# headers may have tuple-typed params with nested parens: match loosely on
# "name ( ... -> ... {" at column 0.
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")


def _is_header(line: str) -> bool:
    s = line.strip()
    return (not line.startswith(" ") and s.endswith("{") and "->" in s
            and "(" in s and "=" not in s.split("(", 1)[0])


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if _is_header(line):
            m = _COMP_NAME_RE.match(line.strip())
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        # long tuple types carry /*index=N*/ comments whose '=' breaks the
        # type matcher — strip comments before parsing
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode = m.groups()
            cur.instrs.append(Instr(name, shape, opcode, line.strip()))
            cur.symtab[name] = shape
    return comps


def _attr_comp(line: str, attr: str) -> Optional[str]:
    m = re.search(rf"{attr}=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


def loop_trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition ≈ the trip count
    (jax scans compare the induction var against constant(T))."""
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def build_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Multiplier per computation from the call graph."""
    edges: List[Tuple[str, str, float]] = []
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                trip = loop_trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    edges.append((c.name, body, float(trip)))
                if cond in comps:
                    edges.append((c.name, cond, float(trip)))
            else:
                for attr in ("calls", "to_apply", "true_computation",
                             "false_computation", "branch_computations"):
                    t = _attr_comp(ins.line, attr)
                    if t and t in comps:
                        edges.append((c.name, t, 1.0))

    children = defaultdict(list)
    called = set()
    for p, ch, t in edges:
        children[p].append((ch, t))
        called.add(ch)
    mult: Dict[str, float] = defaultdict(float)

    def walk(comp: str, factor: float, depth: int):
        if depth > 64:
            return
        mult[comp] = max(mult[comp], factor)
        for ch, t in children.get(comp, []):
            walk(ch, factor * t, depth + 1)

    roots = [c for c in comps if c not in called]
    for r in roots:
        walk(r, 1.0, 0)
    return dict(mult)


def fused_bodies(comps: Dict[str, Computation]) -> Set[str]:
    out = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "fusion":
                t = _attr_comp(ins.line, "calls")
                if t:
                    out.add(t)
    out |= {n for n in comps if "fused_" in n or n.startswith("region")
            and False}
    return out


def _operand_names(line: str, opcode: str) -> List[str]:
    """Operand instruction names of ``opcode(...)``, tolerating both operand
    syntaxes: bare ``%name`` (new dumps) and ``f32[..]{..} %name`` (0.4.x
    prints each operand with its inline type)."""
    m = re.search(rf"{opcode}\(([^)]*)\)", line)
    if not m:
        return []
    return re.findall(r"%([\w\.\-]+)", m.group(1))


def _dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    res = shape_dims(ins.shape)
    if not res:
        return 0.0
    out_elems = sum(math.prod(d) for _, d in res)
    ops = _operand_names(ins.line, "dot")
    k = 1
    if ops:
        lhs_shape = symtab.get(ops[0], "")
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        dims = shape_dims(lhs_shape)
        if mc and dims:
            for di in mc.group(1).split(","):
                if di.strip() != "" and int(di) < len(dims[0][1]):
                    k *= dims[0][1][int(di)]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    res = shape_dims(ins.shape)
    if not res:
        return 0.0
    out_elems = sum(math.prod(d) for _, d in res)
    ops = _operand_names(ins.line, "convolution")
    if len(ops) < 2:
        return 0.0
    rhs = shape_dims(symtab.get(ops[1], ""))
    kernel = math.prod(rhs[0][1]) if rhs else 1
    # flops ≈ 2 * out_elems * (kernel_elems / out_channels); approximate via
    # kernel spatial*in_ch: divide by last dim (out features) when plausible
    if rhs and len(rhs[0][1]) >= 2:
        kernel = kernel // max(rhs[0][1][-1], 1) or 1
    return 2.0 * out_elems * kernel


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_link_bytes: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_coll(self) -> float:
        return sum(self.coll_link_bytes.values())


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    if "source_target_pairs" in line:
        return 2
    return n_devices


def _permute_hops(line: str, n_devices: int) -> float:
    """Max ring hop distance over a collective-permute's pairs.

    On a TPU ring a permute src->dst traverses |dst-src| (mod wraparound)
    links even when intermediate *stages* do no work — the paper's portals
    free devices, not wires (DESIGN.md §2 C4).  The pipeline's shift chain
    is all distance-1; a portal edge (s -> d) pays ring_distance(s, d)."""
    m = re.search(r"source_target_pairs=\{(.*?)\}\s*(?:,|$)", line)
    if not m:
        return 1.0
    pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}")
    if not pairs:
        return 1.0
    # distances are cyclic over the PARTICIPATING id set (the mesh axis is a
    # physical ring: a full rotation's wraparound pair is 1 hop, not
    # |ids|-1 device-ids apart)
    ids = sorted({int(x) for p in pairs for x in p})
    pos = {d: i for i, d in enumerate(ids)}
    g = len(ids)
    best = 1
    for a, b in pairs:
        d = abs(pos[int(b)] - pos[int(a)])
        best = max(best, min(d, max(g - d, 1)))
    return float(best)


def _is_vmem_score(shape_str: str) -> bool:
    """Attention score/probability blocks ([.., Sq, block_k] fp32, >=4 dims)
    are VMEM-resident in the production Pallas flash kernel (and in the
    blocked-jnp path they are loop-local); they must not be charged as HBM
    traffic.  Weights/activations (bf16, or <=3 dims) are never matched."""
    dims = shape_dims(shape_str)
    if not dims:
        return False
    dt, d = dims[0]
    return (dt == "f32" and len(d) >= 3 and d[-1] <= 512 and d[-2] >= 1024)


def _op_operand_bytes(ins: Instr, symtab: Dict[str, str], opname: str) -> int:
    """HBM bytes for a dot/convolution: operands + result, with VMEM-resident
    attention score blocks excluded (see _is_vmem_score)."""
    total = 0 if _is_vmem_score(ins.shape) else shape_bytes(ins.shape)
    m = re.search(rf"{opname}\(\s*%?([\w\.\-]+)\s*,\s*%?([\w\.\-]+)", ins.line)
    if m:
        for op in (m.group(1), m.group(2)):
            s = symtab.get(op, "")
            if not _is_vmem_score(s):
                total += shape_bytes(s)
    return total


def analyze_hlo(hlo: str, n_devices: int) -> HloCost:
    comps = parse_module(hlo)
    mult = build_multipliers(comps)
    cost = HloCost(coll_link_bytes=defaultdict(float),
                   coll_counts=defaultdict(int))
    for c in comps.values():
        f = mult.get(c.name, 0.0)
        if f <= 0:
            continue
        for ins in c.instrs:
            if ins.opcode == "dot":
                cost.flops += f * _dot_flops(ins, c.symtab)
                cost.hbm_bytes += f * _op_operand_bytes(ins, c.symtab, "dot")
            elif ins.opcode == "convolution":
                cost.flops += f * _conv_flops(ins, c.symtab)
                cost.hbm_bytes += f * _op_operand_bytes(ins, c.symtab,
                                                        "convolution")
            kind = next((k for k in _COLL_KINDS
                         if ins.opcode in (k, k + "-start")), None)
            if kind:
                b = shape_bytes(ins.shape)
                g = _group_size(ins.line, n_devices)
                if kind == "all-reduce":
                    lb = 2 * b * (g - 1) / g
                elif kind == "collective-permute":
                    lb = float(b) * _permute_hops(ins.line, n_devices)
                else:
                    lb = b * (g - 1) / g
                cost.coll_link_bytes[kind] += f * lb
                cost.coll_counts[kind] += int(f)
                cost.hbm_bytes += f * b          # collectives touch HBM too
    cost.coll_link_bytes = dict(cost.coll_link_bytes)
    cost.coll_counts = dict(cost.coll_counts)
    return cost


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per device
    bytes_hbm: float             # per device
    coll_bytes: float            # per device link bytes
    coll_detail: Dict[str, float]
    model_flops_per_dev: float   # 6·N·D (or 2·N·D) / n_devices
    n_devices: int
    memory_per_device: float = 0.0
    xla_flops: float = 0.0       # raw cost_analysis (uncorrected), reference
    notes: str = ""
    schedule: str = "gpipe"
    # idle share of the selected schedule's dedicated-device critical path
    # (repro.core.schedules.device_bubble_fraction of the ACTUAL task
    # table — 0 for non-pipelined cells).  The roofline terms below count
    # executed work, which a pipelined step stretches by the bubble; the
    # step-time estimate divides by (1 - bubble) so dry-run numbers track
    # the selected schedule rather than assuming the GPipe clock.
    bubble_fraction: float = 0.0
    hw: HardwareConstants = field(default_factory=lambda: V5E)

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def pipeline_efficiency(self) -> float:
        return 1.0 - self.bubble_fraction

    @property
    def step_time(self) -> float:
        busy = max(self.t_compute, self.t_memory, self.t_collective)
        return busy / max(self.pipeline_efficiency, 1e-9)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_per_dev / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        ideal = self.model_flops_per_dev / self.hw.peak_flops_bf16
        return ideal / self.step_time if self.step_time else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "coll_bytes": self.coll_bytes, "coll_detail": self.coll_detail,
            "model_flops_per_dev": self.model_flops_per_dev,
            "n_devices": self.n_devices,
            "memory_per_device": self.memory_per_device,
            "xla_flops": self.xla_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "schedule": self.schedule,
            "bubble_fraction": self.bubble_fraction,
            "step_time": self.step_time,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "notes": self.notes,
        }


def model_flops_for(arch, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D inference (N = active params)."""
    n_active = arch.active_params_per_token()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch
