"""Native optimizers (no optax in this environment): AdamW, SGD+momentum,
global-norm clipping, warmup+cosine schedules.

Optimizer state mirrors the parameter pytree leaf-for-leaf, so every state
leaf inherits the parameter's sharding (FSDP/ZeRO: moments live sharded over
the ``data`` axis exactly like their parameters — the ZeRO-1/2 part of the
ZeRO-3 story; the parameter all-gather/grad reduce-scatter is GSPMD's job).
Master weights and moments are fp32 regardless of parameter dtype.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (fp32), pytree like params
    nu: Any          # second moment (fp32) — zeros pytree for sgd
    master: Any      # fp32 master copy of params
    ef: Any = ()     # int8-EF gradient-compression residuals (or ())


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9        # sgd
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptimizerConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def init(cfg: OptimizerConfig, params, *, with_ef: bool = False) -> OptState:
    """``with_ef`` allocates the error-feedback residual pytree for int8-EF
    gradient compression (ParallelConfig.grad_compression="int8_ef"); it
    mirrors the params leaf-for-leaf so it shards like the moments."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params),
                    master=master,
                    ef=jax.tree.map(f32, params) if with_ef else ())


def apply(cfg: OptimizerConfig, state: OptState, params, grads
          ) -> Tuple[Any, OptState, dict]:
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)
    step = state.step + 1
    lr = schedule(cfg, step)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p32, m, v):
            mhat = m / c1
            vhat = v / c2
            return p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                               + cfg.weight_decay * p32)
        master = jax.tree.map(upd, state.master, mu, nu)
    elif cfg.name == "sgd":
        mu = jax.tree.map(lambda m, g: cfg.momentum * m + g, state.mu, grads)
        nu = state.nu
        master = jax.tree.map(
            lambda p32, m: p32 - lr * (m + cfg.weight_decay * p32),
            state.master, mu)
    else:
        raise ValueError(f"unknown optimizer {cfg.name!r}")

    new_params = jax.tree.map(lambda p, p32: p32.astype(p.dtype),
                              params, master)
    new_state = OptState(step=step, mu=mu, nu=nu, master=master,
                         ef=state.ef)
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
