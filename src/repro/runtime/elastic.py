"""Elastic re-scaling: rebuild the mesh + stage partition when the healthy
device pool changes.

When a slice is lost (or capacity is added), the framework:

  1. picks the new parallel layout: keep ``tp`` (intra-stage math must stay
     divisible), shrink/grow ``pipe`` then ``data`` to tile the pool;
  2. re-balances layers -> stages with core.balance.block_partition for the
     new pipe degree (the paper's torchgpipe.balance applied elastically);
  3. restacks the stage parameters [old_n, L_old, ...] -> [new_n, L_new, ...]
     — pure reshaping of the layer sequence, so a checkpoint written under
     any layout restores under any other;
  4. re-jits the step (new mesh/shardings).

Resharding cost is one all-gather of the stage weights; at 1000+-node scale
this is the slice-replacement path, not the common path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig
from repro.core import balance as balance_lib
from repro.core import stage as stage_lib


def choose_layout(n_devices: int, old: ParallelConfig,
                  *, min_data: int = 1) -> ParallelConfig:
    """Largest layout tiling ``n_devices`` that preserves tp and respects
    pipe <= old.pipe (stages can merge, never split finer than layers)."""
    tp = old.tp
    if n_devices % tp:
        raise ValueError(f"pool {n_devices} not divisible by tp={tp}")
    rest = n_devices // tp
    best = None
    for pipe in range(min(old.pipe, rest), 0, -1):
        if rest % pipe:
            continue
        data = rest // pipe
        if data < min_data:
            continue
        best = old.with_(pipe=pipe, data=data, pod=1)
        break
    if best is None:
        raise ValueError(f"no layout for {n_devices} devices (tp={tp})")
    return best


def restack_stages(stacked: Any, layer_mask: np.ndarray,
                   new_n: int) -> Tuple[Any, np.ndarray]:
    """[old_n, L_old, ...] stage params -> [new_n, L_new, ...].

    Real layers (mask==1) are flattened in order and re-split with identity
    padding for the new stage count."""
    old_n, L_old = layer_mask.shape
    flat_mask = layer_mask.reshape(-1) > 0
    idx = np.nonzero(flat_mask)[0]
    n_real = len(idx)
    L_new, new_mask = stage_lib.pad_layout(n_real, new_n)

    def one(a):
        flat = a.reshape((old_n * L_old,) + a.shape[2:])
        real = flat[jnp.asarray(idx)]
        pad = jnp.zeros((new_n * L_new - n_real,) + real.shape[1:],
                        real.dtype)
        return jnp.concatenate([real, pad]).reshape(
            (new_n, L_new) + real.shape[1:])

    return jax.tree.map(one, stacked), new_mask


def rebalance_plan(costs: List[float], new_pipe: int) -> List[int]:
    """torchgpipe.balance applied to the new stage count."""
    return balance_lib.block_partition(costs, new_pipe)
