"""int8 error-feedback gradient compression for cross-pod data parallelism.

The pod axis is the slowest link in the production mesh (inter-pod DCN vs
intra-pod ICI).  The cross-pod gradient all-reduce is therefore compressed:
each pod quantizes its local gradient to int8 with a per-block fp32 scale,
all-reduces the int8 payload (4x fewer bytes on the slow link; the
per-block scales ride along at ~1/256 overhead), dequantizes, and keeps the
quantization residual in an *error-feedback* buffer added to the next
step's gradient — the EF-SGD construction whose convergence matches
uncompressed SGD to O(compression-variance) (Seide et al., Karimireddy et
al.).

Implemented as a pure transform on the gradient pytree:

    comp = EFCompressor(block=256)
    grads, ef_state = comp.compress_reduce(grads, ef_state, reduce_fn)

``reduce_fn`` is the (possibly cross-pod) mean; under GSPMD the caller
passes identity (the reduction is implicit in sharding propagation) or an
explicit jax.lax.pmean inside shard_map for the manual path — the transform
is agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def _quantize_block(x: jnp.ndarray, block: int):
    """x: flat fp32 -> (int8 payload, fp32 per-block scales, padded_len)."""
    n = x.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    xp = jnp.pad(x, (0, pad)).reshape(nb, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_block(q, scale, n: int):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


@dataclass(frozen=True)
class EFCompressor:
    block: int = 256

    def init_state(self, grads: Any) -> Any:
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress_reduce(self, grads: Any, ef: Any,
                        reduce_fn: Optional[Callable] = None
                        ) -> Tuple[Any, Any]:
        """Returns (reduced dequantized grads, new error-feedback state)."""
        reduce_fn = reduce_fn or (lambda x: x)

        def one(g, e):
            gf = g.astype(jnp.float32) + e
            flat = gf.reshape(-1)
            q, scale = _quantize_block(flat, self.block)
            deq = _dequantize_block(q, scale, flat.shape[0]).reshape(g.shape)
            new_e = gf - deq                      # residual kept locally
            return reduce_fn(deq), new_e

        # Explicit two-tree flatten/unflatten: flattening the (deq, ef)
        # pair tree with ``is_leaf=isinstance(x, tuple)`` would stop at any
        # tuple NODE a grad pytree legitimately contains and silently
        # mis-split it; the grads treedef pins the leaf positions instead.
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(ef)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        red = jax.tree_util.tree_unflatten(treedef, [r for r, _ in pairs])
        new_ef = jax.tree_util.tree_unflatten(treedef, [e for _, e in pairs])
        return red, new_ef

    def payload_bytes(self, grads: Any) -> Tuple[int, int]:
        """(compressed, uncompressed) cross-link bytes per replica.

        Accepts concrete arrays or abstract leaves (ShapeDtypeStruct — the
        dryrun path sizes the payload from ``jax.eval_shape`` params).
        """
        def n_of(g):
            size = getattr(g, "size", None)
            if size is None:
                size = 1
                for d in g.shape:
                    size *= int(d)
            return int(size)

        sizes = [n_of(g) for g in jax.tree.leaves(grads)]
        raw = sum(n * 4 for n in sizes)
        comp = sum(n + 4 * (-(-n // self.block)) for n in sizes)
        return comp, raw
