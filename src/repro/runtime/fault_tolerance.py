"""Fault-tolerant training supervisor: checkpoint/restart, retries,
preemption simulation, straggler-aware step watchdog.

At thousand-node scale the train loop is a state machine around three
invariants:

  1. every batch is a pure function of (seed, step)  -> data replays exactly
     after restart (data/pipeline.py);
  2. (params, opt_state, step) is atomically checkpointed -> a restart
     resumes bit-identically from the last commit (ckpt/checkpoint.py);
  3. any step may die (preemption, ICI timeout, straggler)  -> the
     supervisor restores and retries with bounded backoff, re-creating the
     compiled step (a new jax client in a real redeploy).

``FaultInjector`` deterministically raises at chosen steps so the tests can
prove invariant 3; ``StepWatchdog`` flags steps exceeding a straggler
multiple of the trailing median (mitigation at this layer = restart from
checkpoint on a healthy slice — see elastic.py for the re-mesh path).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.ckpt.checkpoint import CheckpointManager


class Preemption(RuntimeError):
    """Simulated node loss / SIGTERM-style preemption."""


@dataclass
class FaultInjector:
    fail_at_steps: Sequence[int] = ()
    exc: type = Preemption
    _raised: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._raised:
            self._raised.add(step)
            raise self.exc(f"injected fault at step {step}")


@dataclass
class StepWatchdog:
    """Detects stragglers: steps slower than ``multiple``x the trailing
    median.  On real fleets this triggers slice replacement; here it
    records and (optionally) raises for the supervisor to restart."""
    window: int = 16
    multiple: float = 3.0
    raise_on_straggler: bool = False
    times: List[float] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float):
        hist = sorted(self.times[-self.window:])
        if hist:
            med = hist[len(hist) // 2]
            if dt > self.multiple * max(med, 1e-9):
                self.stragglers.append(step)
                if self.raise_on_straggler:
                    raise Preemption(
                        f"straggler step {step}: {dt:.3f}s vs median {med:.3f}s")
        self.times.append(dt)


@dataclass
class Supervisor:
    """run() drives make_step()/state through n_steps with restart-on-fault.

    make_state(restored) -> state      (build or adopt restored pytree)
    step_fn(state, step)  -> state, metrics
    state_for_ckpt(state) -> pytree    (what to persist)
    """
    ckpt: CheckpointManager
    make_state: Callable[[Optional[Any]], Any]
    step_fn: Callable[[Any, int], Any]
    state_for_ckpt: Callable[[Any], Any] = lambda s: s
    ckpt_every: int = 10
    max_restarts: int = 8
    backoff_s: float = 0.0
    watchdog: Optional[StepWatchdog] = None
    injector: Optional[FaultInjector] = None

    def run(self, n_steps: int) -> Dict[str, Any]:
        restarts = 0
        history: List[Dict] = []
        while True:
            try:
                state, start = self._restore_or_init()
                for step in range(start, n_steps):
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, step)
                    dt = time.perf_counter() - t0
                    if self.watchdog is not None:
                        self.watchdog.observe(step, dt)
                    history.append({"step": step, **metrics})
                    if (step + 1) % self.ckpt_every == 0:
                        self.ckpt.save(step + 1, self.state_for_ckpt(state))
                self.ckpt.save(n_steps, self.state_for_ckpt(state))
                self.ckpt.wait()
                return {"state": state, "history": history,
                        "restarts": restarts}
            except Preemption as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                if self.backoff_s:
                    time.sleep(self.backoff_s * restarts)

    def _restore_or_init(self):
        step = self.ckpt.latest_step()
        if step is None:
            return self.make_state(None), 0
        proto = self.state_for_ckpt(self.make_state(None))
        tree, meta = self.ckpt.restore(step, proto)
        return self.make_state(tree), meta["step"]
