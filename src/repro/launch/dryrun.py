import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (architecture × input shape × mesh) cell: build the step function,
``jax.jit(...).lower(**abstract_inputs).compile()`` against the production
mesh — 16×16 single-pod and 2×16×16 multi-pod — and record
``memory_analysis()`` / ``cost_analysis()`` / the trip-count-corrected HLO
roofline terms into a JSON artifact that EXPERIMENTS.md §Dry-run/§Roofline
read.  A failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the framework.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro import compat
from repro.compat import set_mesh
from repro import configs
from repro.configs.base import SHAPES_BY_NAME, V5E
from repro.core import plan as plan_lib
from repro.core import wire as wire_lib
from repro.runtime.compression import EFCompressor
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sharding_lib
from repro.launch import steps
from repro.models.lm import LMModel
from repro.roofline import analysis


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             keep_hlo: bool = False, pcfg_override=None,
             optimized: bool = False, verbose: bool = True,
             plan_spec=None) -> dict:
    arch = configs.get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    if not configs.shape_applies(arch, shape):
        return {"arch": arch_name, "shape": shape_name, "skipped": True,
                "reason": "long_500k needs sub-quadratic decode "
                          "(full-attention arch; DESIGN.md §4)"}
    pcfg = pcfg_override or configs.get_parallel(arch_name,
                                                 optimized=optimized)
    pcfg = pcfg.with_(pod=2 if multi_pod else 1,
                      n_micro=configs.derive_n_micro(
                          shape, pcfg.with_(pod=2 if multi_pod else 1)))
    if plan_spec is not None:
        # a PlanSpec (planner report entry) overrides the five pipeline
        # knobs wholesale; GSPMD axes (tp/data/pod) stay as derived above.
        # The production grid's model axis is fixed (dp2*pipe*tp), so when
        # the plan was made for fewer ranks than the grid's model axis,
        # the surplus becomes extra data parallelism (dp2).
        model_axis = pcfg.model_axis
        pcfg = plan_spec.apply_to(pcfg)
        want = pcfg.pipe * pcfg.tp
        if model_axis % want:
            raise SystemExit(
                f"plan pipe={pcfg.pipe} x tp={pcfg.tp} does not divide the "
                f"grid's model axis ({model_axis}); re-plan with a "
                f"hardware.yaml whose ranks divide it")
        pcfg = pcfg.with_(dp2=model_axis // want)
        dp = pcfg.pod * pcfg.data * pcfg.dp2 * pcfg.tp
        if (shape.global_batch // pcfg.n_micro) % dp:
            raise SystemExit(
                f"plan m={pcfg.n_micro} gives micro-batches of "
                f"{shape.global_batch // pcfg.n_micro} which do not divide "
                f"the grid's {dp}-way data parallelism; re-plan with "
                f"ranks={model_axis} in hardware.yaml and --dp {dp} so the "
                f"planner sees the full grid")
    base = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh = mesh_lib.make_arch_mesh(pcfg, base=base)
    n_dev = mesh.size
    model = LMModel(arch, pcfg)
    t0 = time.time()
    cell = steps.build_cell(model, pcfg, mesh, shape)
    with set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.abstract_args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled) or {}
    hlo = compiled.as_text()
    cost = analysis.analyze_hlo(hlo, n_dev)
    mf = analysis.model_flops_for(arch, shape) / n_dev
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    # the cost model is schedule-parametric: a train cell's step time is
    # stretched by the SELECTED schedule's dedicated-device bubble (1F1B
    # and GPipe share a critical path; interleaved shrinks the fill by
    # ~1/v; zb fills bubbles with Bw work and, under residuals="reuse",
    # skips Bw's recompute entirely) — not by the GPipe clock
    # unconditionally.  The chain-hop comm term is priced from the
    # roofline constants (boundary bytes over ICI vs one stage-forward of
    # compute) and overlaps the next tick's compute under the mpmd
    # executor's double buffering, serializes after the producing task
    # under spmd.
    comm_units = bwd_comm_units = 0.0
    buf_report = {}
    wire_report = {}
    wspec = pcfg.wire_spec
    if shape.kind == "train" and pcfg.pipe > 1:
        mbg = shape.global_batch // pcfg.n_micro
        act_bytes = 2 if pcfg.activation_dtype == "bfloat16" else 4
        carry_bytes = mbg * shape.seq_len * arch.d_model * act_bytes
        # one stage-forward of compute per micro, in seconds (model FLOPs
        # are fwd+bwd ~ 3x fwd; a stage holds 1/pipe of the layers)
        fwd_unit_s = (analysis.model_flops_for(arch, shape) / 3.0
                      / pcfg.n_micro / pcfg.pipe) / V5E.peak_flops_bf16 \
            / max(pcfg.tp * pcfg.data * pcfg.pod, 1)
        hop_bytes = carry_bytes / max(pcfg.data * pcfg.pod, 1)
        # the wire codec prices each payload class in actual on-the-wire
        # bytes — forward carries at the chain precision, mirrored
        # cotangents at the cotangent precision
        hop_s = (hop_bytes * wire_lib.bytes_factor(wspec.chain,
                                                   block=wspec.block)
                 / V5E.ici_bw)
        bwd_hop_s = (hop_bytes * wire_lib.bytes_factor(wspec.cotangent,
                                                       block=wspec.block)
                     / V5E.ici_bw)
        comm_units = hop_s / fwd_unit_s if fwd_unit_s > 0 else 0.0
        bwd_comm_units = bwd_hop_s / fwd_unit_s if fwd_unit_s > 0 else 0.0
        tplan = plan_lib.plan_for(pcfg.schedule, pcfg.n_micro, pcfg.pipe,
                                  residuals=pcfg.residuals, wire=pcfg.wire)
        buf_report = sharding_lib.per_rank_buffer_bytes(tplan, carry_bytes)
        wire_report = wire_lib.plan_wire_report(tplan, carry_bytes)
    bubble = (plan_lib.schedule_bubble(pcfg.schedule, pcfg.n_micro,
                                       pcfg.pipe,
                                       residuals=pcfg.residuals,
                                       remat=pcfg.remat,
                                       executor=pcfg.executor,
                                       comm_cost=comm_units,
                                       bwd_comm_cost=bwd_comm_units)
              if shape.kind == "train" else 0.0)
    rep = analysis.RooflineReport(
        arch=arch_name, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        flops=cost.flops, bytes_hbm=cost.hbm_bytes,
        coll_bytes=cost.total_coll, coll_detail=cost.coll_link_bytes,
        model_flops_per_dev=mf, n_devices=n_dev,
        memory_per_device=per_dev_bytes,
        xla_flops=float(ca.get("flops", 0.0)),
        schedule=pcfg.schedule, bubble_fraction=round(bubble, 4),
        notes=f"pipe={pcfg.pipe} tp={pcfg.tp} m={pcfg.n_micro} "
              f"sched={pcfg.schedule} residuals={pcfg.residuals} "
              f"executor={pcfg.executor}")
    out = rep.to_dict()
    out.update({
        "skipped": False,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "coll_counts": cost.coll_counts,
        "memory_analysis": {
            "argument_size": mem.argument_size_in_bytes,
            "output_size": mem.output_size_in_bytes,
            "temp_size": mem.temp_size_in_bytes,
            "alias_size": mem.alias_size_in_bytes,
        },
        "pcfg": {"pipe": pcfg.pipe, "tp": pcfg.tp, "data": pcfg.data,
                 "pod": pcfg.pod, "n_micro": pcfg.n_micro,
                 "remat": pcfg.remat, "residuals": pcfg.residuals,
                 "executor": pcfg.executor, "wire": pcfg.wire,
                 "grad_compression": pcfg.grad_compression},
        "comm_cost_units": round(comm_units, 4),
        "bwd_comm_cost_units": round(bwd_comm_units, 4),
        "advisories": list(pcfg.advisories()),
    })
    if buf_report:
        out["tick_buffers"] = buf_report
    if wire_report:
        out["wire"] = wire_report
    if shape.kind == "train" and pcfg.grad_compression == "int8_ef":
        # sizing from abstract params — no allocation, just the bytes the
        # cross-pod gradient all-reduce puts on the slow link per replica
        comp, raw = EFCompressor().payload_bytes(
            steps.abstract_params(model))
        out["grad_compression"] = {
            "mode": "int8_ef", "payload_bytes": comp,
            "uncompressed_bytes": raw,
            "ratio": round(comp / max(raw, 1), 4)}
    if verbose:
        print(f"[dryrun] {arch_name}/{shape_name} mesh={out['mesh']} "
              f"pipe={pcfg.pipe} tp={pcfg.tp} m={pcfg.n_micro} "
              f"executor={pcfg.executor} "
              f"compile={out['compile_s']}s "
              f"mem/dev={per_dev_bytes/2**30:.2f}GiB "
              f"t=(c {rep.t_compute*1e3:.1f} | m {rep.t_memory*1e3:.1f} | "
              f"x {rep.t_collective*1e3:.1f}) ms "
              f"bottleneck={rep.bottleneck} "
              f"roofline={rep.roofline_fraction:.3f}")
        print(f"[dryrun]   memory_analysis: {mem}")
        if buf_report:
            # per-rank (NOT uniform-max): what each rank's specialized
            # program declares for its park/inbox/residual slots.  The
            # byte figures cover park + inbox only — residual-slot bytes
            # are trace-time geometry (resid_info via build_train_step /
            # the schedules bench), so slots are printed but not priced.
            park = buf_report["per_rank_park_slots"]
            resid = buf_report["per_rank_resid_slots"]
            bb = buf_report["per_rank_buffer_bytes"]
            print(f"[dryrun]   per-rank park slots={park} "
                  f"resid slots={resid} (resid bytes are trace-time) "
                  f"park+inbox MiB={[round(b / 2**20, 1) for b in bb]} "
                  f"(uniform-max/rank "
                  f"{buf_report['uniform_max_buffer_bytes_per_rank'] / 2**20:.1f}"
                  f" MiB)")
        if wire_report:
            print(f"[dryrun]   wire={wire_report['wire']} "
                  f"bytes/tick={wire_report['bytes_per_tick']:.0f} "
                  f"ratio={wire_report['ratio']:.3f}")
        if "grad_compression" in out:
            gc = out["grad_compression"]
            print(f"[dryrun]   grad_compression=int8_ef "
                  f"payload={gc['payload_bytes']/2**20:.1f}MiB "
                  f"(raw {gc['uncompressed_bytes']/2**20:.1f}MiB, "
                  f"ratio {gc['ratio']:.3f})")
        for msg in pcfg.advisories():
            print(f"[dryrun]   ADVISORY: {msg}")
    if keep_hlo:
        out["hlo"] = hlo
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="use the §Perf-hillclimbed parallel configs")
    ap.add_argument("--plan", default=None,
                    help="PlanReport JSON (from `hillclimb --hardware "
                         "... --out`); applies its top feasible plan")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    plan_spec = None
    if args.plan:
        from repro.planner.report import PlanReport
        with open(args.plan) as f:
            report = PlanReport.from_json(f.read())
        best = report.best
        if best is None:
            raise SystemExit(f"{args.plan}: no feasible plan in the report")
        plan_spec = best.spec
        print(f"[dryrun] applying plan: schedule={plan_spec.schedule.name} "
              f"residuals={plan_spec.schedule.residuals} "
              f"executor={plan_spec.schedule.executor} "
              f"m={plan_spec.microbatches} "
              f"partition={list(plan_spec.partition) or 'uniform'}")

    cells = []
    if args.all:
        for a in configs.ARCH_NAMES:
            for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for mp in meshes:
        for a, s in cells:
            try:
                results.append(run_cell(a, s, multi_pod=mp,
                                        optimized=args.optimized,
                                        plan_spec=plan_spec))
            except Exception as e:   # a dry-run failure is a framework bug
                traceback.print_exc()
                results.append({"arch": a, "shape": s,
                                "mesh": "2x16x16" if mp else "16x16",
                                "skipped": False, "error": str(e)[:500]})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} cells -> {args.out}")
    errs = [r for r in results if r.get("error")]
    if errs:
        raise SystemExit(f"{len(errs)} cells FAILED: "
                         f"{[(r['arch'], r['shape']) for r in errs]}")


if __name__ == "__main__":
    main()
