import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver (§Perf): re-run a dry-run cell under parallel-config
overrides and print the roofline delta vs the recorded baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb \\
        --arch deepseek-7b --shape train_4k \\
        --set gather_weights_once=True pipe=8 tp=2
"""
import argparse
import ast
import json

from repro import configs
from repro.launch.dryrun import run_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="ParallelConfig overrides, e.g. pipe=8 tp=2")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    pcfg = configs.get_parallel(args.arch).with_(**overrides)
    r = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 pcfg_override=pcfg)
    if args.out:
        json.dump(r, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
