import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Planner CLI (and legacy perf-iteration driver).

Planner mode — give it a hardware description and it searches microbatch
count x schedule x residuals x executor x balance partition with the
calibrated device model, prints the ranked PlanReport, and optionally
writes it as JSON for ``dryrun --plan`` / ``PlanSpec.from_dict``:

    PYTHONPATH=src python -m repro.launch.hillclimb \\
        --arch smollm-360m --shape train_4k \\
        --hardware hardware.yaml --top 5 [--out plan.json] [--smoke]

Legacy mode (no ``--hardware``) — re-run a dry-run cell under manual
ParallelConfig overrides and print the roofline delta:

    PYTHONPATH=src python -m repro.launch.hillclimb \\
        --arch deepseek-7b --shape train_4k \\
        --set gather_weights_once=True pipe=8 tp=2
"""
import argparse
import ast
import json

from repro import configs
from repro.configs.base import SHAPES_BY_NAME


def _plan(args) -> None:
    from repro.planner import HardwareSpec, microbatch_options, plan_arch

    hw = HardwareSpec.from_yaml(args.hardware)
    if args.smoke:
        arch = configs.smoke_arch(args.arch)
    else:
        arch = configs.get_arch(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    ms = None
    if args.dp > 1:
        # micro-batches must still shard over the surrounding data axis
        # (e.g. dryrun's production grid) — restrict the enumeration
        ms = microbatch_options(shape.global_batch, hw.ranks, args.dp)
    wires = ([w.strip() for w in args.wires.split(";") if w.strip()]
             if args.wires else None)
    report = plan_arch(arch, shape, hw, microbatches=ms, wires=wires)
    print(report.format_table(args.top))
    best = report.best
    if best is not None:
        s = best.spec
        print(f"\n[plan] best: schedule={s.schedule.name} "
              f"residuals={s.schedule.residuals} "
              f"executor={s.schedule.executor} m={s.microbatches} "
              f"partition={list(s.partition) or 'uniform'}")
        print(f"[plan] wire: {s.wire} — "
              f"{best.wire_bytes_per_step / 2**20:.1f} MiB on the wire "
              f"per step ({best.wire_ratio:.2f}x fp32)")
        print("[plan] apply with: "
              "PlanSpec.from_dict(report['candidates'][0]['spec'])"
              ".apply_to(pcfg)")
    if args.out:
        with open(args.out, "w") as f:
            f.write(report.to_json())
        print(f"[plan] wrote PlanReport -> {args.out}")
    if best is None:
        raise SystemExit("no feasible plan under the memory budget")


def _legacy(args) -> None:
    from repro.launch.dryrun import run_cell

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    pcfg = configs.get_parallel(args.arch).with_(**overrides)
    r = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 pcfg_override=pcfg)
    if args.out:
        json.dump(r, open(args.out, "w"), indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--hardware", default=None,
                    help="hardware.yaml path; enables planner mode")
    ap.add_argument("--top", type=int, default=5,
                    help="planner mode: rows of the ranked table to print")
    ap.add_argument("--smoke", action="store_true",
                    help="planner mode: plan the reduced smoke variant")
    ap.add_argument("--wires", default="fp32;bf16;int8-ef",
                    help="planner mode: ';'-separated WireSpec strings the "
                         "wire-precision search enumerates (each may be a "
                         "uniform codec or 'chain=...,portal=...,"
                         "cotangent=...'); empty = hardware.yaml's wire")
    ap.add_argument("--dp", type=int, default=1,
                    help="planner mode: surrounding data-parallel ways the "
                         "micro-batch must shard over (set to the grid's "
                         "data axis when feeding --plan to dryrun)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="legacy mode: ParallelConfig overrides, e.g. pipe=8")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.hardware:
        _plan(args)
    else:
        _legacy(args)


if __name__ == "__main__":
    main()
