"""Production mesh construction (assignment spec) + derived arch meshes.

``make_production_mesh`` is exactly the assignment's canonical grid:
``(data=16, model=16)`` per pod, ``(pod=2, data=16, model=16)`` multi-pod.
Per architecture, the ``model`` axis factors into ``pipe × tp`` over the same
device grid (MaxText-style ici_pipeline × ici_tensor) via
:func:`make_arch_mesh`; the ``tp`` axis is innermost so tensor-parallel
collectives ride adjacent ICI links while the pipeline's single-hop
``collective-permute`` tolerates the stride.

Nothing here touches jax device state at import time — meshes are built
inside functions only.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh

# AxisType only exists on jax >= 0.5; repro.compat supplies a no-op enum (and
# axis_types-tolerant constructors) on 0.4.x so collection never breaks.
from repro.compat import AxisType, make_mesh, mesh_with_axis_types
from repro.configs.base import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes),
                     devices=jax.devices()[:n])


def make_arch_mesh(pcfg: ParallelConfig, *, base: Optional[Mesh] = None) -> Mesh:
    """Refine the production mesh's ``model`` axis into ``pipe × tp``.

    Returns a 4-axis mesh ``(pod, data, pipe, tp)`` over the identical device
    grid (pod=1 single-pod).  Falls back to whatever devices exist when the
    full 256/512 grid is unavailable (smoke tests pass pipe/tp/data of 1).
    """
    if base is None:
        base = make_production_mesh(multi_pod=pcfg.pod > 1)
    devs = np.asarray(base.devices)
    if devs.ndim == 2:
        devs = devs[None]                       # (pod=1, data, model)
    pod, data, model = devs.shape
    if (pod, data) != (pcfg.pod, pcfg.data) or pcfg.model_axis != model:
        raise ValueError(
            f"parallel config (pod={pcfg.pod}, data={pcfg.data}, "
            f"pipe={pcfg.pipe}, tp={pcfg.tp}, dp2={pcfg.dp2}) does not tile "
            f"the production grid {devs.shape}")
    # model axis factors as (dp2, pipe, tp): surplus model-axis capacity for
    # small architectures becomes extra data parallelism (dp2), keeping the
    # assignment's canonical (data, model) grid intact.
    grid = devs.reshape(pod, data, pcfg.dp2, pcfg.pipe, pcfg.tp) \
        .reshape(pod, data * pcfg.dp2, pcfg.pipe, pcfg.tp)
    return mesh_with_axis_types(grid, ("pod", "data", "pipe", "tp"),
                                axis_types=(AxisType.Auto,) * 4)


# The chain-collective topology lives next to the plan IR (one definition
# for the executor, comm accounting, and tests); re-exported here because
# mesh construction is where device-topology questions get asked first.
from repro.core.plan import pipe_ring_perm  # noqa: E402,F401


def make_smoke_mesh(pcfg: ParallelConfig) -> Mesh:
    """Mesh over however many local devices the reduced configs use."""
    n = pcfg.pod * pcfg.data * pcfg.pipe * pcfg.tp
    devs = np.array(jax.devices()[:n]).reshape(
        pcfg.pod, pcfg.data, pcfg.pipe, pcfg.tp)
    return mesh_with_axis_types(devs, ("pod", "data", "pipe", "tp"),
                                axis_types=(AxisType.Auto,) * 4)
