"""ShardingPlan: NamedSharding assignment for every array in a step.

Conventions (DESIGN.md §3):
  * stage parameters [n_stages, L_per_stage, ...]: 'pipe' on axis 0; the
    trailing weight dims get FSDP ('data') on the input-ish dim and TP
    ('tp') on the output-ish dim (reversed for output projections so the TP
    all-reduce lands after the second matmul); MoE experts get EP ('tp') on
    the expert dim.  Every assignment checks divisibility and degrades to
    replication per-dim otherwise.
  * embed/head: vocab over 'data' (FSDP), d_model over 'tp'.
  * optimizer state mirrors its parameter leaf-for-leaf.
  * batch: leading dim over ('pod', 'data').
  * KV caches [n_stages, L, m, mb, slots, kv, hd]: 'pipe' + micro-batch over
    ('pod','data') when divisible, otherwise the slots dim over 'data'
    (sequence-sharded long-context decode; GSPMD inserts the LSE reductions).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH = ("pod", "data")

# per-leaf-name TP placement: which trailing dim gets 'tp'
_TP_IN = {"wo", "wd", "wv_cm"}        # output projections: tp on input dim
_EXPERT = {"wg", "wu", "wd"}          # under a "moe" subtree: dim0 = experts


def _axsize(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= _axsize(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.axis_names else 0


def _fit(dim: int, mesh: Mesh, axis) -> Any:
    n = _axsize(mesh, axis)
    return axis if n > 0 and dim % n == 0 else None


def stage_param_spec(path: Tuple[str, ...], leaf, mesh: Mesh) -> P:
    """PartitionSpec for one stacked stage-parameter leaf."""
    name = path[-1]
    in_moe = "moe" in path
    shape = leaf.shape
    nd = leaf.ndim
    # axes 0,1 = (n_stages, L_per_stage)
    rest = [None] * (nd - 2)
    # FSDP dim shards over (pod, data) jointly: ZeRO-3 spans *all* data
    # parallelism so optimizer state (which mirrors these specs) scales with
    # the full DP degree — required to fit llama3-405b's Adam state.
    fsdp = (("pod", "data") if _axsize(mesh, "pod") > 1 else "data")
    if nd >= 4 and in_moe and name in _EXPERT:
        # [n, L, E, din, dout]
        rest[0] = _fit(shape[2], mesh, "tp")
        rest[1] = _fit(shape[3], mesh, fsdp) or _fit(shape[3], mesh, "data")
    elif nd == 4:
        din, dout = shape[2], shape[3]
        if name in _TP_IN:
            rest[0] = _fit(din, mesh, "tp")
            rest[1] = _fit(dout, mesh, fsdp) or _fit(dout, mesh, "data")
        else:
            rest[0] = _fit(din, mesh, fsdp) or _fit(din, mesh, "data")
            rest[1] = _fit(dout, mesh, "tp")
    elif nd == 3 and shape[2] >= 1024:
        rest[0] = _fit(shape[2], mesh, fsdp) or _fit(shape[2], mesh, "data")
    return P("pipe", None, *rest)


def param_specs(params, mesh: Mesh) -> Any:
    """Specs for the full {"embed","stages","head"} tree."""
    def embed_spec(path, leaf):
        # Embedding tables shard on d_model, NOT vocab: a vocab-sharded
        # gather makes the SPMD partitioner emit a select-style all-reduce
        # that XLA-CPU's AllReducePromotion cannot clone for bf16 (hard
        # crash), and on TPU it costs an extra all-reduce of the gathered
        # activations anyway.  d_model-sharding keeps the gather local.
        if leaf.ndim == 2:
            return P(None, _fit(leaf.shape[1], mesh, BATCH)
                     or _fit(leaf.shape[1], mesh, "data")
                     or _fit(leaf.shape[1], mesh, "tp"))
        return P()

    def head_spec(path, leaf):
        # Head weight [D, V]: vocab over 'tp' only; replicated over data.
        # The loss-chunk matmul then contracts locally with batch-sharded h
        # (no collective per chunk; one dw all-reduce per step).  Sharding D
        # makes every chunk's logits a [B, c, V] all-reduce (~100 GB/step at
        # 100k vocab); sharding V over 'data' conflicts with the batch
        # sharding and forces h all-gathers — both measured worse
        # (EXPERIMENTS.md §Perf iterations 5-6).
        if leaf.ndim == 2:
            return P(None, _fit(leaf.shape[1], mesh, "tp"))
        return P()

    out = {}
    for top, sub in params.items():
        if top == "stages":
            out[top] = jax.tree_util.tree_map_with_path(
                lambda p, l: stage_param_spec(
                    tuple(getattr(k, "key", str(k)) for k in p), l, mesh),
                sub)
        elif top == "embed":
            out[top] = jax.tree_util.tree_map_with_path(
                lambda p, l: embed_spec(p, l), sub)
        else:
            out[top] = jax.tree_util.tree_map_with_path(
                lambda p, l: head_spec(p, l), sub)
    return out


def batch_specs(batch_proto, mesh: Mesh = None) -> Any:
    def spec(l):
        if mesh is not None:
            ax = (_fit(l.shape[0], mesh, BATCH)
                  or _fit(l.shape[0], mesh, "data"))
            return P(ax, *([None] * (l.ndim - 1)))
        return P(BATCH, *([None] * (l.ndim - 1)))
    return jax.tree.map(spec, batch_proto)


def cache_specs(cache_proto, mesh: Mesh, *, seq_shard: bool = False) -> Any:
    """[n_stages, L, m, mb, ...] resident cache specs."""
    def spec(leaf):
        nd = leaf.ndim
        rest = [None] * (nd - 4)
        mb = leaf.shape[3] if nd > 3 else 0
        mb_ax = None
        if mb and mb % max(_axsize(mesh, BATCH), 1) == 0 and \
                _axsize(mesh, BATCH) > 1 and not seq_shard:
            mb_ax = BATCH
        elif nd >= 6:
            # shard the slots (sequence) dim over data instead
            rest[0] = _fit(leaf.shape[4], mesh, "data")
        if nd >= 7:
            rest[1] = _fit(leaf.shape[5], mesh, "tp")
        if nd == 3:                          # e.g. "len": [n, L, m]
            return P("pipe")
        return P("pipe", None, None, mb_ax, *rest)
    return jax.tree.map(spec, cache_proto)


def drop_fsdp(spec: P) -> P:
    """Remove the data/pod (FSDP) axes from a spec, keeping pipe/tp."""
    def clean(e):
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x not in ("data", "pod"))
            return kept if kept else None
        return None if e in ("data", "pod") else e
    return P(*[clean(e) for e in spec])


def gather_stage_weights(stages, mesh: Mesh):
    """gather_weights_once: constrain stage weights to their un-FSDP'd specs
    so GSPMD all-gathers them once per step (outside the clock loop) instead
    of re-gathering every tick; the constraint's transpose reduce-scatters
    the gradients once on the way out."""
    import jax.tree_util as jtu

    def one(path, leaf):
        spec = stage_param_spec(
            tuple(getattr(k, "key", str(k)) for k in path), leaf, mesh)
        return jax.lax.with_sharding_constraint(leaf, drop_fsdp(spec))
    return jtu.tree_map_with_path(one, stages)


def per_rank_buffer_bytes(tplan, carry_bytes: int,
                          resid_bytes_per_slot: int = 0) -> dict:
    """Donated tick-loop buffer accounting per pipe rank, from the plan.

    Returns, for each rank, the bytes its SPECIALIZED program declares
    (``plan.specialize``: the rank's own park / backward-inbox / residual
    slot high-water x bytes per slot) next to the flattened SPMD
    allocation (every rank at the ring-max depth).  The dryrun roofline
    and the schedules bench report both so the MPMD win — 1F1B's rank 0
    parks 0 slots, not ``max_j`` — is visible per rank instead of being
    averaged away.
    """
    from repro.core import plan as plan_lib

    progs = [plan_lib.specialize(tplan, r) for r in range(tplan.n_ranks)]
    per_rank = [p.park_depth * carry_bytes + p.b_inbox_depth * carry_bytes
                + p.resid_depth * resid_bytes_per_slot for p in progs]
    uniform = tplan.n_ranks * (
        (tplan.park_depth + tplan.b_inbox_depth) * carry_bytes
        + tplan.resid_depth * resid_bytes_per_slot)
    return {
        "per_rank_park_slots": [p.park_depth for p in progs],
        "per_rank_resid_slots": [p.resid_depth for p in progs],
        "per_rank_buffer_bytes": per_rank,
        "uniform_max_buffer_bytes_per_rank": (
            (tplan.park_depth + tplan.b_inbox_depth) * carry_bytes
            + tplan.resid_depth * resid_bytes_per_slot),
        "total_buffer_bytes": {"mpmd_declared": sum(per_rank),
                               "spmd_uniform": uniform},
    }


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(pspecs, opt_state_proto):
    """Mirror parameter specs onto OptState (step is replicated; the EF
    residual pytree — present when grad_compression="int8_ef" — shards
    like the moments)."""
    from repro.optim.optimizers import OptState
    has_ef = len(jax.tree_util.tree_leaves(opt_state_proto.ef)) > 0
    return OptState(step=P(), mu=pspecs, nu=pspecs, master=pspecs,
                    ef=pspecs if has_ef else ())
