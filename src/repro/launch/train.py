"""End-to-end training driver: data pipeline -> pipelined train step ->
optimizer -> async checkpoints, under the fault-tolerance supervisor.

CPU-runnable (reduced configs) and production-launchable (full configs on a
real mesh):

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

``--smoke`` uses the reduced arch + 1-device mesh; otherwise the full
assigned config and the arch's production pipe x tp layout are used
(requires the matching device pool).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim
from repro.runtime.fault_tolerance import FaultInjector, StepWatchdog, Supervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject preemptions at these steps (demo/testing)")
    args = ap.parse_args()

    if args.smoke:
        arch = configs.smoke_arch(args.arch)
        pcfg = configs.smoke_parallel(args.arch)
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        dtype = jnp.float32
    else:
        arch = configs.get_arch(args.arch)
        pcfg = configs.get_parallel(args.arch)
        mesh = mesh_lib.make_arch_mesh(pcfg)
        dtype = jnp.bfloat16

    shape = ShapeConfig("train", args.seq_len, args.batch, "train")
    pcfg = pcfg.with_(n_micro=configs.derive_n_micro(shape, pcfg))
    model = LMModel(arch, pcfg, dtype=dtype)
    ocfg = optim.OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                                 total_steps=args.steps)
    data = SyntheticLM(DataConfig(seed=0, vocab=arch.vocab,
                                  seq_len=args.seq_len,
                                  global_batch=args.batch), arch)
    print(f"[train] {arch.name}: {arch.total_params()/1e6:.1f}M params, "
          f"pipe={pcfg.pipe} tp={pcfg.tp} m={pcfg.n_micro} "
          f"mesh={dict(mesh.shape)}")

    with set_mesh(mesh):
        step_fn_jit = jax.jit(
            steps.build_train_step(model, pcfg, mesh, shape, ocfg))

    def make_state(restored):
        if restored is not None:
            print(f"[train] restored checkpoint")
            return restored
        params = model.init(jax.random.PRNGKey(0))
        return {"params": params,
                "opt": optim.init(
                    ocfg, params,
                    with_ef=pcfg.grad_compression == "int8_ef")}

    log_every = max(1, args.steps // 20)

    def step_fn(state, i):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        t0 = time.perf_counter()
        with set_mesh(mesh):
            p, o, m = step_fn_jit(state["params"], state["opt"], batch)
        loss = float(m["loss"])
        if i % log_every == 0:
            print(f"[train] step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"dt {time.perf_counter()-t0:.2f}s")
        return {"params": p, "opt": o}, {"loss": loss}

    sup = Supervisor(
        ckpt=CheckpointManager(args.ckpt_dir, keep=2),
        make_state=make_state, step_fn=step_fn,
        ckpt_every=args.ckpt_every,
        watchdog=StepWatchdog(),
        injector=FaultInjector(fail_at_steps=tuple(args.fail_at)))
    out = sup.run(args.steps)
    losses = [h["loss"] for h in out["history"]]
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"restarts={out['restarts']}, "
          f"stragglers={len(sup.watchdog.stragglers)}")


if __name__ == "__main__":
    main()
