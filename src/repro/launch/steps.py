"""Step builders: train / prefill / serve through the GPipe pipeline.

Each builder returns a pure function ready for ``jax.jit`` plus the sharding
specs the dry-run / drivers need.  All batch shapes are GLOBAL — GSPMD owns
the (pod, data, tp) axes; the pipeline shard_map owns ``pipe``.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ParallelConfig, ShapeConfig
from repro.core import balance
from repro.core.pipeline import (last_stage_output, microbatch, pipeline_call,
                                 pipeline_grad_call, unmicrobatch)
from repro.launch import sharding
from repro.models.lm import LMModel
from repro.optim import optimizers as optim
from repro.runtime.compression import EFCompressor


def _carry_proto(model: LMModel, mbg: int, seq: int):
    return {"h": jax.ShapeDtypeStruct((mbg, seq, model.arch.d_model),
                                      model.dtype)}


def _maybe_compress_grads(pcfg: ParallelConfig, grads, opt_state):
    """int8-EF the DP gradient reduce (grad_compression="int8_ef").

    The quantize/dequantize + residual update runs before the optimizer;
    under GSPMD the cross-replica mean is implicit in sharding propagation,
    so ``reduce_fn`` stays identity and the transform prices/ships the int8
    payload on the slow (cross-pod) link.  Returns the (possibly) rewritten
    grads plus the new EF residual pytree to store on the OptState.
    """
    if pcfg.grad_compression != "int8_ef":
        return grads, opt_state.ef
    if not jax.tree_util.tree_leaves(opt_state.ef):
        raise ValueError(
            "grad_compression='int8_ef' needs the error-feedback residual "
            "on the optimizer state: initialize it with "
            "optim.init(ocfg, params, with_ef=True)")
    return EFCompressor().compress_reduce(grads, opt_state.ef)


def stage_partition(arch: ArchConfig, pcfg: ParallelConfig, *,
                    by: str = "flops", seq_len: int = 0) -> Tuple[int, ...]:
    """Balanced layer -> stage cuts for ``pcfg`` (torchgpipe.balance, wired).

    Partitions the arch's layers over ``pipe * virtual_stages`` GLOBAL
    stages with the exact contiguous minimax partitioner, weighting layers
    by analytic per-layer flops (``by="flops"``; pass ``seq_len`` for the
    attention quadratic term) or parameter bytes (``by="size"``) from
    :func:`repro.core.balance.arch_layer_costs`.  Feed the result to
    ``pcfg.with_(partition=...)`` — the model assembly scatters layers and
    their constants accordingly.
    """
    if by not in ("flops", "size"):
        raise ValueError(f"unknown balance objective {by!r}; "
                         "want 'flops' or 'size'")
    n_stages = pcfg.pipe * pcfg.virtual_stages
    flops, pbytes = balance.arch_layer_costs(arch, seq_len)
    costs = flops if by == "flops" else pbytes
    return tuple(balance.block_partition(costs, n_stages))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def build_train_step(model: LMModel, pcfg: ParallelConfig, mesh: Mesh,
                     shape: ShapeConfig,
                     ocfg: Optional[optim.OptimizerConfig] = None,
                     resid_info: Optional[dict] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``pcfg.schedule`` selects the execution order: the default ``"gpipe"``
    runs the forward clock-cycle and lets autodiff induce the reverse
    clock-cycle; ``"1f1b"`` / ``"gpipe_tasked"`` / ``"interleaved:v"`` /
    ``"zb"`` run the fused scheduler, where backward tasks execute inside
    the tick loop per the task table (see repro.core.plan) and the
    activation stash is sized structurally.  ``pcfg.residuals="reuse"``
    turns on ZB-H1 residual reuse for split-backward schedules; pass a
    dict as ``resid_info`` to receive the residual-stash geometry (leaf
    shapes, bytes per slot) when the step first traces.
    ``pcfg.executor`` selects the plan lowering: ``"spmd"`` (rank-uniform
    reference) or ``"mpmd"`` (per-rank specialized programs with the
    chain permute double-buffered one tick ahead — bitwise-identical
    results, see :func:`repro.core.pipeline.run_pipeline_tasks`).
    """
    ocfg = ocfg or optim.OptimizerConfig()
    # Gate known config smells at selection time: zb + recompute prices
    # Bx+Bw at 4 stage-forwards per micro (vs fused B's 3), which the
    # device model shows LOSING to 1f1b in low-bubble regimes; the
    # advisory recommends residuals="reuse" (true ZB-H1).
    for msg in pcfg.advisories():
        warnings.warn(msg, stacklevel=2)
    spec = pcfg.schedule_spec            # structured view of the knobs
    if spec.base in ("1f1b", "gpipe_tasked", "interleaved", "zb"):
        return _build_train_step_fused(model, pcfg, mesh, shape, ocfg,
                                       resid_info=resid_info)
    if spec.base != "gpipe":
        raise ValueError(f"unknown schedule {pcfg.schedule!r}; want 'gpipe', "
                         "'gpipe_tasked', '1f1b', 'interleaved:v', or 'zb'")
    consts = model.consts()
    stage_apply = model.make_stage_apply(consts)
    mbg = shape.global_batch // pcfg.n_micro
    pipe = pipeline_call(
        stage_apply, mesh=mesh, cfg=pcfg, skips=model.skips(),
        skip_protos=model.skip_protos(mbg, shape.seq_len),
        carry_proto=_carry_proto(model, mbg, shape.seq_len))

    def loss_fn(params, batch):
        fresh = model.embed_inputs(params["embed"], batch)
        inputs_mb = microbatch(fresh, pcfg.n_micro)
        stages = params["stages"]
        if pcfg.gather_weights_once:
            stages = sharding.gather_stage_weights(stages, mesh)
        outs, _ = pipe(stages, inputs_mb, None)
        h = unmicrobatch(last_stage_output(outs)["h"])
        return model.head_loss(params, h, batch["labels"])

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, new_ef = _maybe_compress_grads(pcfg, grads, opt_state)
        params2, opt2, metrics = optim.apply(ocfg, opt_state, params, grads)
        opt2 = opt2._replace(ef=new_ef)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


def _build_train_step_fused(model: LMModel, pcfg: ParallelConfig, mesh: Mesh,
                            shape: ShapeConfig, ocfg: optim.OptimizerConfig,
                            resid_info: Optional[dict] = None):
    """Schedule-driven train step: the pipeline computes its own gradients.

    The fused executor returns stage grads, head grads, and per-micro input
    cotangents; only the (cheap, GSPMD-land) embedding VJP remains outside
    the pipeline.  Tied-embedding models route part of the table's gradient
    through the head loss — both contributions are summed here.  Skip edges
    (enc-dec portals) and streamed inputs lower into the same plan the
    executor runs, so every ``cfg.schedule`` covers every workload.
    """
    consts = model.consts()
    stage_apply = model.make_stage_apply(consts)
    mbg = shape.global_batch // pcfg.n_micro

    def micro_loss(head_ps, carry, largs):
        return model.head_loss(head_ps, carry["h"], largs["labels"])

    pipe_grad, _ = pipeline_grad_call(
        stage_apply, mesh=mesh, cfg=pcfg, loss_fn=micro_loss,
        skips=model.skips(),
        skip_protos=model.skip_protos(mbg, shape.seq_len),
        carry_proto=_carry_proto(model, mbg, shape.seq_len),
        resid_info=resid_info)

    def train_step(params, opt_state, batch):
        fresh, embed_vjp = jax.vjp(
            lambda emb: model.embed_inputs(emb, batch), params["embed"])
        inputs_mb = microbatch(fresh, pcfg.n_micro)
        labels_mb = microbatch({"labels": batch["labels"]}, pcfg.n_micro)
        head_ps = {"head": params["head"], "embed": params["embed"]}
        loss, g_stage, g_head, ig = pipe_grad(params["stages"], head_ps,
                                              inputs_mb, labels_mb)
        (g_embed,) = embed_vjp(unmicrobatch(ig))
        g_embed = jax.tree.map(jnp.add, g_embed, g_head["embed"])
        grads = {"embed": g_embed, "stages": g_stage, "head": g_head["head"]}
        grads, new_ef = _maybe_compress_grads(pcfg, grads, opt_state)
        params2, opt2, metrics = optim.apply(ocfg, opt_state, params, grads)
        opt2 = opt2._replace(ef=new_ef)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def build_prefill_step(model: LMModel, pcfg: ParallelConfig, mesh: Mesh,
                       shape: ShapeConfig):
    """prefill_step(params, cache, batch) -> (last_token_logits, cache)."""
    consts = model.consts()
    stage_apply = model.make_stage_apply(consts, prefill=True)
    mbg = shape.global_batch // pcfg.n_micro
    pipe = pipeline_call(
        stage_apply, mesh=mesh, cfg=pcfg, skips=model.skips(),
        skip_protos=model.skip_protos(mbg, shape.seq_len),
        carry_proto=_carry_proto(model, mbg, shape.seq_len))

    def prefill_step(params, cache, batch):
        fresh = model.embed_inputs(params["embed"], batch)
        inputs_mb = microbatch(fresh, pcfg.n_micro)
        outs, cache = pipe(params["stages"], inputs_mb, cache)
        h = unmicrobatch(last_stage_output(outs)["h"])
        logits = model.head_logits(params, h[:, -1:, :])
        return logits, cache

    return prefill_step


# ---------------------------------------------------------------------------
# Decode / serve
# ---------------------------------------------------------------------------

def build_serve_step(model: LMModel, pcfg: ParallelConfig, mesh: Mesh,
                     shape: ShapeConfig):
    """serve_step(params, cache, tokens) -> (logits [B,1,V], cache).

    One decode tick: the request batch is micro-batched through the pipeline
    exactly like training (the paper's schedule reused for inference)."""
    consts = model.consts()
    stage_apply = model.make_stage_apply_decode(consts)
    mbg = shape.global_batch // pcfg.n_micro
    pipe = pipeline_call(stage_apply, mesh=mesh, cfg=pcfg,
                         carry_proto=_carry_proto(model, mbg, 1))

    def serve_step(params, cache, tokens):
        h = model.embed_decode(params["embed"], tokens, pos=shape.seq_len)
        inputs_mb = microbatch({"h": h}, pcfg.n_micro)
        outs, cache = pipe(params["stages"], inputs_mb, cache)
        h1 = unmicrobatch(last_stage_output(outs)["h"])
        return model.head_logits(params, h1), cache

    return serve_step


# ---------------------------------------------------------------------------
# Sharded jit assembly for a full cell (used by dryrun + drivers)
# ---------------------------------------------------------------------------

@dataclass
class CompiledCell:
    fn: Callable
    in_shardings: Tuple
    abstract_args: Tuple
    kind: str


def abstract_params(model: LMModel):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def build_cell(model: LMModel, pcfg: ParallelConfig, mesh: Mesh,
               shape: ShapeConfig,
               ocfg: Optional[optim.OptimizerConfig] = None) -> CompiledCell:
    """Assemble the jit-able step + shardings + abstract args for one cell."""
    params_p = abstract_params(model)
    pspecs = sharding.param_specs(params_p, mesh)
    pshard = sharding.named(pspecs, mesh)
    batch_p = model.input_specs(shape)
    bshard = sharding.named(sharding.batch_specs(batch_p, mesh), mesh)

    if shape.kind == "train":
        step = build_train_step(model, pcfg, mesh, shape, ocfg)
        opt_p = jax.eval_shape(
            functools.partial(optim.init, ocfg or optim.OptimizerConfig(),
                              with_ef=pcfg.grad_compression == "int8_ef"),
            params_p)
        ospecs = sharding.opt_state_specs(pspecs, opt_p)
        oshard = sharding.named(ospecs, mesh)
        return CompiledCell(step, (pshard, oshard, bshard),
                            (params_p, opt_p, batch_p), "train")

    cache_p = model.cache_protos(shape, pcfg.n_micro)
    cshard = sharding.named(
        sharding.cache_specs(cache_p, mesh,
                             seq_shard=shape.global_batch <
                             mesh.shape.get("data", 1) *
                             mesh.shape.get("pod", 1)), mesh)
    if shape.kind == "prefill":
        step = build_prefill_step(model, pcfg, mesh, shape)
        return CompiledCell(step, (pshard, cshard, bshard),
                            (params_p, cache_p, batch_p), "prefill")

    step = build_serve_step(model, pcfg, mesh, shape)
    tok_p = batch_p["tokens"]
    tshard = sharding.named(sharding.batch_specs(tok_p, mesh), mesh)
    return CompiledCell(step, (pshard, cshard, tshard),
                        (params_p, cache_p, tok_p), "decode")
