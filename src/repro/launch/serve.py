"""Serving driver: batched prefill + pipelined decode loop.

Both phases execute forward-only plans on the unified schedule runtime
(``run_pipeline_tasks`` via ``pipeline_call``): the resident KV caches are
plan events — read and updated only on each rank's scheduled F ticks, per
micro-batch slot — rather than tick-loop special cases.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \\
        --prompt-len 32 --gen 16 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.smoke:
        arch = configs.smoke_arch(args.arch)
        pcfg = configs.smoke_parallel(args.arch)
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        dtype = jnp.float32
    else:
        arch = configs.get_arch(args.arch)
        pcfg = configs.get_parallel(args.arch)
        mesh = mesh_lib.make_arch_mesh(pcfg)
        dtype = jnp.bfloat16

    max_len = args.prompt_len + args.gen
    pshape = ShapeConfig("prefill", args.prompt_len, args.batch, "prefill")
    dshape = ShapeConfig("decode", max_len, args.batch, "decode")
    pcfg = pcfg.with_(n_micro=configs.derive_n_micro(pshape, pcfg))
    model = LMModel(arch, pcfg, dtype=dtype)
    params = model.init(jax.random.PRNGKey(0))

    with set_mesh(mesh):
        prefill = jax.jit(steps.build_prefill_step(model, pcfg, mesh, pshape))
        decode = jax.jit(steps.build_serve_step(model, pcfg, mesh, dshape))
        cache = model.init_cache(dshape, pcfg.n_micro, filled=False)

        key = jax.random.PRNGKey(1)
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, arch.vocab)
        batch = {"tokens": prompts}
        if arch.is_encdec:
            batch = {"frames": jax.random.normal(
                key, (args.batch, args.prompt_len, arch.d_model)) * 0.1,
                "dec_tokens": prompts}
        if arch.frontend == "vision_stub":
            batch["patches"] = jax.random.normal(
                key, (args.batch, 256, arch.d_model)).astype(dtype) * 0.1

        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        print(f"[serve] prefill {args.batch}x{args.prompt_len} "
              f"in {t_prefill:.3f}s")

        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        generated = [tokens]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tokens)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tokens = jax.random.categorical(
                    sub, logits[:, 0] / args.temperature)[:, None]
                tokens = tokens.astype(jnp.int32)
            else:
                tokens = jnp.argmax(logits, -1).astype(jnp.int32)
            generated.append(tokens)
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        toks = np.concatenate([np.asarray(t) for t in generated], axis=1)
        print(f"[serve] decoded {args.gen - 1} steps x {args.batch} seqs in "
              f"{dt:.3f}s ({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
        print(f"[serve] sample tokens: {toks[0][:12].tolist()}")
        assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
