"""Deterministic synthetic data pipeline with host-side prefetch.

Production shape: an infinite, *restartable* token stream — every batch is a
pure function of (seed, step), so a job restarted from step k reproduces the
exact remaining stream (a fault-tolerance requirement: see
runtime/fault_tolerance.py).  Per-host sharding follows the batch's
(pod, data) layout: each process materializes only its slice and the arrays
are assembled with jax.make_array_from_process_local_data in multi-host
deployments (single-host here: device_put with the batch sharding).

The synthetic distribution mimics an LM corpus shape-wise: Zipfian token
ids, document boundaries every ~doc_len tokens, labels = next token.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    zipf_a: float = 1.2
    doc_len: int = 512
    prefetch: int = 2


class SyntheticLM:
    """Deterministic synthetic LM batches: batch(step) is pure."""

    def __init__(self, cfg: DataConfig, arch=None):
        self.cfg = cfg
        self.arch = arch

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        B, S = cfg.global_batch, cfg.seq_len
        # zipf-ish ids via inverse-power transform, bounded to vocab
        u = rng.random((B, S + 1))
        ids = np.minimum((u ** (-1.0 / cfg.zipf_a) - 1.0).astype(np.int64),
                         cfg.vocab - 1).astype(np.int32)
        # document boundaries: reset marker token 0
        pos = np.arange(S + 1)[None, :]
        offs = rng.integers(0, cfg.doc_len, (B, 1))
        ids = np.where((pos + offs) % cfg.doc_len == 0, 0, ids)
        out = {"tokens": ids[:, :S], "labels": ids[:, 1:]}
        if self.arch is not None and self.arch.is_encdec:
            d = self.arch.d_model
            out = {
                "frames": rng.standard_normal((B, S, d)).astype(np.float32) * 0.1,
                "dec_tokens": ids[:, :S], "labels": ids[:, 1:],
            }
        elif self.arch is not None and self.arch.frontend == "vision_stub":
            d = self.arch.d_model
            out["patches"] = rng.standard_normal((B, 256, d)).astype(np.float32) * 0.1
        return out

    def stream(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Host-side prefetch thread: overlaps batch synthesis + device_put with
    the step computation (the data-pipeline analogue of the paper's copy
    streams: input copies never block compute)."""

    def __init__(self, it: Iterator, device_put_fn=None, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._put = device_put_fn or (lambda x: x)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(self._put(item))
        except BaseException as e:   # surfaced on next __next__
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise (self._err or StopIteration)
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_sharded_loader(cfg: DataConfig, mesh, batch_shardings, arch=None,
                        start_step: int = 0) -> Prefetcher:
    ds = SyntheticLM(cfg, arch)

    def put(b):
        return {k: jax.device_put(v, batch_shardings[k])
                if k in batch_shardings else jnp.asarray(v)
                for k, v in b.items()}

    return Prefetcher(ds.stream(start_step), put, cfg.prefetch)
