"""Heterogeneous (switch-based) pipeline programs: U-Net / AmoebaNet.

LM stages are homogeneous (stacked params); conv nets change channel counts
and resolutions per stage, so each stage gets its own branch under
``lax.switch(stage_idx, ...)`` (core/stage.py rationale).  Stage boundaries
carry a flat fp32 activation buffer padded to the largest boundary.

Skip connections crossing stage boundaries follow paper §3.3:
  * portals=True  — each skip rides a dedicated single-pair
    collective-permute + destination ring (repro.core.skip);
  * portals=False — the skip is packed INTO the boundary buffer and hops
    through every intermediate stage (the symptomatic case; the buffer and
    hence every ``collective-permute`` gets wider, which the ablation
    benchmark measures).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig
from repro.core import stage as stage_lib
from repro.core.pipeline import (last_stage_output, microbatch,
                                 pipeline_call, pipeline_grad_call,
                                 unmicrobatch)
from repro.core.skip import SkipSpec


@dataclass
class HeteroProgram:
    stacked_params: Any             # [n_stages, max_flat] fp32
    stage_apply: Callable           # pipeline StageApplyFn
    carry_proto: Any                # {"buf": SDS([mb, max_elems])}
    skips: List[SkipSpec]
    skip_protos: Dict[str, Any]
    out_proto: Any                  # final stage output pytree proto


def _buffer_proto(protos: Sequence[Any], mb: int) -> int:
    return max(stage_lib.buffer_elems(p) for p in protos)


def build_hetero_program(model, params, mb: int, pcfg: ParallelConfig,
                         example_input) -> HeteroProgram:
    """Compile a layer-list model (UNetModel/AmoebaNetModel API) into a
    switch-based pipeline program.

    model must expose: layers, bounds, n_stages, layer_apply(i, p, x, skips),
    and (for skip routing) optional .skip_edges().
    """
    n = model.n_stages
    bounds = model.bounds

    # one abstract pass: boundary activation shapes, skip tensor shapes,
    # and which stage produces/consumes each skip
    stage_of = np.zeros(len(model.layers), int)
    for s in range(n):
        stage_of[bounds[s]:bounds[s + 1]] = s
    produced_at: Dict[str, int] = {}
    consumed_at: Dict[str, int] = {}
    skip_shapes: Dict[str, Any] = {}
    boundary_x: List[Any] = [jax.eval_shape(lambda v: v, example_input)]
    x = boundary_x[0]
    store: Dict[str, Any] = {}
    for i, l in enumerate(model.layers):
        def step(v, st, _i=i):
            st = dict(st)
            out = model.layer_apply(_i, params[_i], v, st)
            return out, st
        x, store = jax.eval_shape(step, x, store)
        store = dict(store)
        skip_shapes.update(store)
        if getattr(l, "skip_out", None):
            produced_at[l.skip_out] = int(stage_of[i])
        if getattr(l, "skip_in", None):
            consumed_at[l.skip_in] = int(stage_of[i])
        if i + 1 in bounds[1:]:
            boundary_x.append(x)
    out_proto = x

    # skips crossing stage boundaries
    crossing = {k: (produced_at[k], consumed_at[k])
                for k in produced_at
                if k in consumed_at and consumed_at[k] > produced_at[k]}
    use_portals = pcfg.portals
    portal_edges = [SkipSpec(k, int(s), (int(d),))
                    for k, (s, d) in crossing.items()] if use_portals else []

    # per-stage boundary protos: x plus (threaded mode) live crossing skips
    def live_at(s):
        return {k: None for k, (src, dst) in crossing.items()
                if src < s <= dst} if not use_portals else {}

    in_protos, out_protos = [], []
    for s in range(n):
        xin = {"x": boundary_x[s],
               **{k: skip_shapes[k] for k in live_at(s)}}
        xout = {"x": boundary_x[s + 1],
                **{k: skip_shapes[k] for k in live_at(s + 1)}}
        in_protos.append(xin)
        out_protos.append(xout)
    max_elems = _buffer_proto(in_protos + out_protos, mb)

    # flat-pack the per-stage params
    flats, treedefs, shapess = [], [], []
    for s in range(n):
        f, td, sh = stage_lib.flatten_params(params[bounds[s]:bounds[s + 1]])
        flats.append(f)
        treedefs.append(td)
        shapess.append(sh)
    size = max(f.shape[0] for f in flats)
    stacked = jnp.stack([jnp.pad(f, (0, size - f.shape[0])) for f in flats])

    skip_protos = {e.name: skip_shapes[e.name] for e in portal_edges}

    def make_branch(s: int):
        def branch(flat_params, buf, skips_in):
            p_list = stage_lib.unflatten_params(flat_params, treedefs[s],
                                                shapess[s])
            xin = stage_lib.unpack_buffer(buf, in_protos[s])
            x = xin.pop("x")
            store = dict(xin)
            for e in portal_edges:
                if e.dsts[0] == s:
                    store[e.name] = skips_in[e.name]
            outs = {}
            for li in range(bounds[s], bounds[s + 1]):
                x = model.layer_apply(li, p_list[li - bounds[s]], x, store)
            # zero skips take the RUNTIME batch (x.shape[0]): inside the
            # old-jax fully-manual region the local batch is 1/bdiv of the
            # proto's global batch, and switch branches must agree.
            skips_out = {e.name: (store[e.name] if e.name in store
                                  else jnp.zeros(
                                      (x.shape[0],)
                                      + tuple(skip_protos[e.name].shape[1:]),
                                      skip_protos[e.name].dtype))
                         for e in portal_edges}
            pack = {"x": x}
            for k in live_at(s + 1):
                pack[k] = store[k]
            return stage_lib.pack_buffer(pack, max_elems), skips_out
        return branch

    branches = [make_branch(s) for s in range(n)]

    def stage_apply(stage_params, carry, skips_in, resident, ctx):
        buf_in = jnp.where(ctx.stage == 0, ctx.fresh["buf"], carry["buf"])
        sidx = jnp.clip(ctx.stage, 0, n - 1)
        buf, skips_out = jax.lax.switch(sidx, branches, stage_params,
                                        buf_in, skips_in)
        return {"buf": buf}, skips_out, resident

    carry_proto = {"buf": jax.ShapeDtypeStruct((mb, max_elems), jnp.float32)}
    return HeteroProgram(stacked, stage_apply, carry_proto, portal_edges,
                         skip_protos, out_proto)


def hetero_forward(program: HeteroProgram, mesh, pcfg: ParallelConfig,
                   x_batch):
    """Full pipelined forward: x [B, ...] -> y [B, ...] (last stage out)."""
    pipe = pipeline_call(program.stage_apply, mesh=mesh, cfg=pcfg,
                         skips=program.skips,
                         skip_protos=program.skip_protos,
                         carry_proto=program.carry_proto)
    B = x_batch.shape[0]
    mb = B // pcfg.n_micro
    max_elems = program.carry_proto["buf"].shape[1]
    bufs = stage_lib.pack_buffer({"x": x_batch}, max_elems)
    inputs_mb = microbatch({"buf": bufs}, pcfg.n_micro)
    outs, _ = pipe(program.stacked_params, inputs_mb, None)
    buf = unmicrobatch(last_stage_output(outs))["buf"]
    out_shape = jax.ShapeDtypeStruct((B,) + tuple(program.out_proto.shape[1:]),
                                     program.out_proto.dtype)
    return stage_lib.unpack_buffer(buf, {"x": out_shape})["x"]


def hetero_grad_call(program: HeteroProgram, mesh, pcfg: ParallelConfig,
                     resid_info: Optional[dict] = None):
    """Fused schedule-driven training call for a hetero (switch) program.

    The portal skip edges lower into the unified executor's plan, so the
    U-Net / AmoebaNet pipelines train under any ``pcfg.schedule`` (GPipe or
    1F1B) with the same bitwise-stable gradients as the LM path — including
    ``"zb"`` with ``pcfg.residuals="reuse"`` (pass a dict as ``resid_info``
    to receive the residual-stash geometry at trace time).  Returns
    ``call(stacked_params, x [B, ...], y [B, ...]) -> (loss, grads)``:
    loss is the mean-squared error of the final stage output against ``y``
    and grads mirror ``stacked_params``.
    """
    max_elems = program.carry_proto["buf"].shape[1]
    out_elems = int(np.prod(program.out_proto.shape[1:]))

    def micro_loss(head_ps, carry, largs):
        y = carry["buf"][:, :out_elems]
        return jnp.mean((y - largs["y"]) ** 2)

    pipe_grad, _ = pipeline_grad_call(
        program.stage_apply, mesh=mesh, cfg=pcfg, loss_fn=micro_loss,
        skips=program.skips, skip_protos=program.skip_protos,
        carry_proto=program.carry_proto, resid_info=resid_info)

    def call(stacked_params, x_batch, y_batch):
        bufs = stage_lib.pack_buffer({"x": x_batch}, max_elems)
        inputs_mb = microbatch({"buf": bufs}, pcfg.n_micro)
        y_flat = y_batch.reshape(y_batch.shape[0], -1).astype(jnp.float32)
        labels_mb = microbatch({"y": y_flat}, pcfg.n_micro)
        loss, g_stage, _, _ = pipe_grad(stacked_params, {}, inputs_mb,
                                        labels_mb)
        return loss, g_stage

    return call
