"""Per-family transformer blocks: dense / moe / rwkv6 / hybrid / encdec.

Every block family exposes the same four functions so the LM assembly
(:mod:`repro.models.lm`) and the pipeline stage program stay family-agnostic:

  init(key, arch, dtype)                 -> per-layer params pytree
  apply(p, h, consts, arch, memory=None) -> h          (train / prefill)
  decode(p, h, consts, arch, cache, memory_scale)      (one token, cache)
  cache_proto(arch, batch, max_len)      -> per-layer cache pytree protos

``consts`` is the per-layer constant record sliced from the stacked
``[n_stages, L_per_stage]`` buffers: identity mask, sliding-window size,
causal flag, cross-attention flag (see lm.build_consts).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import layers as L


def _res(h, mask, delta):
    """Residual add gated by the identity-padding mask (dtype-preserving)."""
    return h + (delta.astype(jnp.float32) * mask).astype(h.dtype)


def _window_arg(arch: ArchConfig, consts):
    """Static int window for uniform layouts; traced const for mixed."""
    a = arch.attn
    if a is None:
        return None
    if a.global_layers:
        return consts["window"]          # traced per-layer
    return int(a.window) if a.kind == "swa" else None


# ---------------------------------------------------------------------------
# Dense (smollm / gemma / llama3 / deepseek / pixtral / whisper enc+dec)
# ---------------------------------------------------------------------------

def dense_init(key, arch: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    out_scale = (2 * (arch.n_layers + arch.enc_layers)) ** -0.5
    p = {
        "ln1": L.norm_init(arch.d_model, arch.norm, dtype),
        "attn": L.attn_init(ks[0], arch.d_model, arch.attn, dtype,
                            out_scale=out_scale),
        "ln2": L.norm_init(arch.d_model, arch.norm, dtype),
        "mlp": L.mlp_init(ks[1], arch.d_model, arch.d_ff, arch.act, dtype,
                          out_scale=out_scale),
    }
    if arch.is_encdec:
        p["lnx"] = L.norm_init(arch.d_model, arch.norm, dtype)
        p["xattn"] = L.attn_init(ks[2], arch.d_model, arch.attn, dtype,
                                 out_scale=out_scale)
    return p


def dense_apply(p, h, consts, arch: ArchConfig, memory=None):
    a = arch.attn
    mask = consts["mask"]
    causal = consts["causal"] if arch.is_encdec else None
    kv_len = consts.get("attn_len")
    win = _window_arg(arch, consts)
    attn = L.attn_apply(p["attn"], L.norm_apply(p["ln1"], h, arch.norm), a,
                        window=win, causal=causal, kv_len=kv_len)
    h = _res(h, mask, attn)
    if arch.is_encdec:
        x = L.attn_apply(p["xattn"], L.norm_apply(p["lnx"], h, arch.norm), a,
                         memory=memory, causal=0, kv_len=consts.get("mem_len"))
        h = _res(h, mask * consts["cross"], x)
    mlp = L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], h, arch.norm), arch.act)
    return _res(h, mask, mlp)


def dense_decode(p, h, consts, arch: ArchConfig, cache):
    a = arch.attn
    mask = consts["mask"]
    win = int(a.window) if a.kind == "swa" else (
        None if not a.global_layers else consts["window"])
    swa = a.kind == "swa" or bool(a.global_layers)
    attn, cache["self"] = L.attn_decode(
        p["attn"], L.norm_apply(p["ln1"], h, arch.norm), cache["self"], a,
        window=(win if swa else None))
    h = _res(h, mask, attn)
    if arch.is_encdec:
        x, _ = L.attn_decode(p["xattn"], L.norm_apply(p["lnx"], h, arch.norm),
                             cache["cross"], a, cross=True)
        h = _res(h, mask * consts["cross"], x)
    mlp = L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], h, arch.norm), arch.act)
    return _res(h, mask, mlp), cache


def dense_cache_proto(arch: ArchConfig, batch: int, max_len: int, dtype):
    a = arch.attn
    slots = min(max_len, a.window) if a.kind == "swa" else max_len
    c = {"self": {
        "k": jax.ShapeDtypeStruct((batch, slots, a.n_kv_heads, a.head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, slots, a.n_kv_heads, a.head_dim), dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32)}}
    if arch.is_encdec:
        c["cross"] = {
            "k": jax.ShapeDtypeStruct((batch, arch.enc_len or max_len,
                                       a.n_kv_heads, a.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((batch, arch.enc_len or max_len,
                                       a.n_kv_heads, a.head_dim), dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}
    return c


# ---------------------------------------------------------------------------
# MoE (mixtral / dbrx): dense attention + routed experts
# ---------------------------------------------------------------------------

def moe_init(key, arch: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    out_scale = (2 * arch.n_layers) ** -0.5
    return {
        "ln1": L.norm_init(arch.d_model, arch.norm, dtype),
        "attn": L.attn_init(ks[0], arch.d_model, arch.attn, dtype,
                            out_scale=out_scale),
        "ln2": L.norm_init(arch.d_model, arch.norm, dtype),
        "moe": L.moe_init(ks[1], arch.d_model, arch.d_ff, arch.moe, dtype,
                          out_scale=out_scale),
    }


def moe_apply(p, h, consts, arch: ArchConfig, memory=None):
    mask = consts["mask"]
    win = _window_arg(arch, consts)
    attn = L.attn_apply(p["attn"], L.norm_apply(p["ln1"], h, arch.norm),
                        arch.attn, window=win)
    h = _res(h, mask, attn)
    out, _ = L.moe_apply(p["moe"], L.norm_apply(p["ln2"], h, arch.norm),
                         arch.moe)
    return _res(h, mask, out)


def moe_decode(p, h, consts, arch: ArchConfig, cache):
    mask = consts["mask"]
    a = arch.attn
    win = int(a.window) if a.kind == "swa" else None
    attn, cache["self"] = L.attn_decode(
        p["attn"], L.norm_apply(p["ln1"], h, arch.norm), cache["self"], a,
        window=win)
    h = _res(h, mask, attn)
    out, _ = L.moe_apply(p["moe"], L.norm_apply(p["ln2"], h, arch.norm),
                         arch.moe, group_size=h.shape[0] * h.shape[1])
    return _res(h, mask, out), cache


moe_cache_proto = dense_cache_proto


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

def rwkv_init(key, arch: ArchConfig, dtype):
    d, f = arch.d_model, arch.d_ff
    hd = 64
    H = d // hd
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        "ln1": L.norm_init(d, arch.norm, dtype),
        "tm": {
            "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(dtype),
            "wr": L.dense_init(ks[1], d, d, dtype),
            "wk": L.dense_init(ks[2], d, d, dtype),
            "wv": L.dense_init(ks[3], d, d, dtype),
            "wg": L.dense_init(ks[4], d, d, dtype),
            "w_base": jnp.zeros((d,), jnp.float32),
            "ww1": L.dense_init(ks[5], d, lora, dtype),
            "ww2": L.dense_init(ks[6], lora, d, dtype, 0.1),
            "u": (jax.random.normal(ks[7], (H, hd)) * 0.1).astype(jnp.float32),
            "gn_scale": jnp.ones((d,), dtype),
            "wo": L.dense_init(ks[8], d, d, dtype,
                               (2 * arch.n_layers) ** -0.5),
        },
        "ln2": L.norm_init(d, arch.norm, dtype),
        "cm": {
            "mu": (jax.random.uniform(ks[9], (2, d)) * 0.5).astype(dtype),
            "wk": L.dense_init(ks[10], d, f, dtype),
            "wv": L.dense_init(ks[11], f, d, dtype, (2 * arch.n_layers) ** -0.5),
            "wr": L.dense_init(ks[0], d, d, dtype),
        },
    }


def _token_shift(x, last=None):
    """Previous-token features: shift right by one along S."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _rwkv_time_mix(tm, x, arch: ArchConfig, state0=None, last=None):
    B, S, D = x.shape
    hd = 64
    H = D // hd
    xs = _token_shift(x, last)
    mu = tm["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i][None, None] * (xs - x) for i in range(5))
    r = (xr @ tm["wr"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (xk @ tm["wk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (xv @ tm["wv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ tm["wg"])
    wlog = tm["w_base"][None, None] + jnp.tanh(xw @ tm["ww1"]) @ tm["ww2"]
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32))) \
        .reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    out, state = ops.wkv6(r, k, v, w, tm["u"], state0)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, D)
    out = ops.rmsnorm(out.astype(x.dtype), tm["gn_scale"])
    return (out * g.astype(out.dtype)) @ tm["wo"], state, x[:, -1:]


def _rwkv_channel_mix(cm, x, last=None):
    xs = _token_shift(x, last)
    mu = cm["mu"].astype(x.dtype)
    xk = x + mu[0][None, None] * (xs - x)
    xr = x + mu[1][None, None] * (xs - x)
    k = jnp.square(jax.nn.relu(L.ffn_tp(xk @ cm["wk"])))
    return jax.nn.sigmoid(xr @ cm["wr"]) * (k @ cm["wv"]), x[:, -1:]


def rwkv_apply(p, h, consts, arch: ArchConfig, memory=None):
    mask = consts["mask"]
    tmix, _, _ = _rwkv_time_mix(p["tm"], L.norm_apply(p["ln1"], h, arch.norm),
                                arch)
    h = _res(h, mask, tmix)
    cmix, _ = _rwkv_channel_mix(p["cm"], L.norm_apply(p["ln2"], h, arch.norm))
    return _res(h, mask, cmix)


def rwkv_decode(p, h, consts, arch: ArchConfig, cache):
    mask = consts["mask"]
    x1 = L.norm_apply(p["ln1"], h, arch.norm)
    tmix, state, last = _rwkv_time_mix(p["tm"], x1, arch,
                                       state0=cache["state"],
                                       last=cache["last_tm"])
    cache["state"], cache["last_tm"] = state, last
    h = _res(h, mask, tmix)
    x2 = L.norm_apply(p["ln2"], h, arch.norm)
    cmix, last2 = _rwkv_channel_mix(p["cm"], x2, last=cache["last_cm"])
    cache["last_cm"] = last2
    return _res(h, mask, cmix), cache


def rwkv_cache_proto(arch: ArchConfig, batch: int, max_len: int, dtype):
    d = arch.d_model
    hd = 64
    H = d // hd
    return {
        "state": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
        "last_tm": jax.ShapeDtypeStruct((batch, 1, d), dtype),
        "last_cm": jax.ShapeDtypeStruct((batch, 1, d), dtype),
    }


# ---------------------------------------------------------------------------
# Hybrid (hymba): parallel attention + SSM heads, then MLP
# ---------------------------------------------------------------------------

def hybrid_init(key, arch: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    out_scale = (2 * arch.n_layers) ** -0.5
    return {
        "ln1": L.norm_init(arch.d_model, arch.norm, dtype),
        "attn": L.attn_init(ks[0], arch.d_model, arch.attn, dtype,
                            out_scale=out_scale),
        "ssm": L.ssm_init(ks[1], arch.d_model, arch.ssm, dtype),
        "ln2": L.norm_init(arch.d_model, arch.norm, dtype),
        "mlp": L.mlp_init(ks[2], arch.d_model, arch.d_ff, arch.act, dtype,
                          out_scale=out_scale),
    }


def hybrid_apply(p, h, consts, arch: ArchConfig, memory=None):
    mask = consts["mask"]
    x = L.norm_apply(p["ln1"], h, arch.norm)
    attn = L.attn_apply(p["attn"], x, arch.attn, window=consts["window"])
    ssm, _ = L.ssm_scan(p["ssm"], x, arch.ssm)
    h = _res(h, mask, 0.5 * (attn.astype(jnp.float32)
                             + ssm.astype(jnp.float32)))
    mlp = L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], h, arch.norm), arch.act)
    return _res(h, mask, mlp)


def hybrid_decode(p, h, consts, arch: ArchConfig, cache):
    mask = consts["mask"]
    x = L.norm_apply(p["ln1"], h, arch.norm)
    attn, cache["self"] = L.attn_decode(p["attn"], x, cache["self"], arch.attn,
                                        window=consts["window"])
    ssm, cache["state"] = L.ssm_decode(p["ssm"], x, cache["state"], arch.ssm)
    h = _res(h, mask, 0.5 * (attn.astype(jnp.float32)
                             + ssm.astype(jnp.float32)))
    mlp = L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], h, arch.norm), arch.act)
    return _res(h, mask, mlp), cache


GLOBAL_WINDOW = 32768
"""Bounded window used for a hybrid arch's 'global' attention layers at
ultra-long contexts: stacked per-stage caches must be shape-uniform across
layers, so the few global layers share the SWA ring-cache layout with a much
larger window.  Exact for contexts <= 32k; an explicit bounded-memory
approximation beyond (DESIGN.md §4)."""


def hybrid_cache_proto(arch: ArchConfig, batch: int, max_len: int, dtype):
    a = arch.attn
    s = arch.ssm
    H = s.n_heads or arch.d_model // s.head_dim
    slots = min(max_len, max(a.window, GLOBAL_WINDOW) if a.global_layers
                else (a.window or max_len))
    return {
        "self": {
            "k": jax.ShapeDtypeStruct((batch, slots, a.n_kv_heads, a.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((batch, slots, a.n_kv_heads, a.head_dim), dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32)},
        "state": jax.ShapeDtypeStruct((batch, H, s.head_dim, s.state_dim),
                                      jnp.float32),
    }


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward + cache population
# ---------------------------------------------------------------------------

def _ring_fill(seq_kv, slots: int):
    """Place the last min(S, slots) positions of [B, S, H, hd] into ring
    order: ring[s] holds position p ≡ s (mod slots), the largest such p < S."""
    S = seq_kv.shape[1]
    if S <= slots:
        pad = slots - S
        return jnp.pad(seq_kv, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = jnp.arange(slots)
    p = s + ((S - 1 - s) // slots) * slots
    return jnp.take(seq_kv, p, axis=1)


def _fill_self_cache(p, h_normed, a, cache):
    B, S, _ = h_normed.shape
    k = (h_normed @ p["wk"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    v = (h_normed @ p["wv"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    if a.use_rope:
        k = L.rope(k, jnp.arange(S), a.rope_theta)
    slots = cache["k"].shape[1]
    return {"k": _ring_fill(k, slots).astype(cache["k"].dtype),
            "v": _ring_fill(v, slots).astype(cache["v"].dtype),
            "len": jnp.asarray(S, jnp.int32)}


def dense_prefill(p, h, consts, arch: ArchConfig, cache, memory=None):
    hn = L.norm_apply(p["ln1"], h, arch.norm)
    cache["self"] = _fill_self_cache(p["attn"], hn, arch.attn, cache["self"])
    h2 = dense_apply(p, h, consts, arch, memory=memory)
    if arch.is_encdec and memory is not None:
        a = arch.attn
        Bm, Sm, _ = memory.shape
        mk = (memory @ p["xattn"]["wk"]).reshape(Bm, Sm, a.n_kv_heads, a.head_dim)
        mv = (memory @ p["xattn"]["wv"]).reshape(Bm, Sm, a.n_kv_heads, a.head_dim)
        slots = cache["cross"]["k"].shape[1]
        cache["cross"] = {"k": _ring_fill(mk, slots).astype(cache["cross"]["k"].dtype),
                          "v": _ring_fill(mv, slots).astype(cache["cross"]["v"].dtype),
                          "len": jnp.asarray(Sm, jnp.int32)}
    return h2, cache


def moe_prefill(p, h, consts, arch: ArchConfig, cache, memory=None):
    hn = L.norm_apply(p["ln1"], h, arch.norm)
    cache["self"] = _fill_self_cache(p["attn"], hn, arch.attn, cache["self"])
    return moe_apply(p, h, consts, arch), cache


def rwkv_prefill(p, h, consts, arch: ArchConfig, cache, memory=None):
    mask = consts["mask"]
    x1 = L.norm_apply(p["ln1"], h, arch.norm)
    tmix, state, last = _rwkv_time_mix(p["tm"], x1, arch)
    cache["state"], cache["last_tm"] = state, last.astype(cache["last_tm"].dtype)
    h = _res(h, mask, tmix)
    x2 = L.norm_apply(p["ln2"], h, arch.norm)
    cmix, last2 = _rwkv_channel_mix(p["cm"], x2)
    cache["last_cm"] = last2.astype(cache["last_cm"].dtype)
    return _res(h, mask, cmix), cache


def hybrid_prefill(p, h, consts, arch: ArchConfig, cache, memory=None):
    mask = consts["mask"]
    x = L.norm_apply(p["ln1"], h, arch.norm)
    cache["self"] = _fill_self_cache(p["attn"], x, arch.attn, cache["self"])
    attn = L.attn_apply(p["attn"], x, arch.attn, window=consts["window"])
    ssm, state = L.ssm_scan(p["ssm"], x, arch.ssm)
    cache["state"] = state
    h = _res(h, mask, 0.5 * (attn.astype(jnp.float32)
                             + ssm.astype(jnp.float32)))
    mlp = L.mlp_apply(p["mlp"], L.norm_apply(p["ln2"], h, arch.norm), arch.act)
    return _res(h, mask, mlp), cache


FAMILIES = {
    "dense": (dense_init, dense_apply, dense_decode, dense_cache_proto,
              dense_prefill),
    "encdec": (dense_init, dense_apply, dense_decode, dense_cache_proto,
               dense_prefill),
    "vlm": (dense_init, dense_apply, dense_decode, dense_cache_proto,
            dense_prefill),
    "moe": (moe_init, moe_apply, moe_decode, moe_cache_proto, moe_prefill),
    "ssm": (rwkv_init, rwkv_apply, rwkv_decode, rwkv_cache_proto,
            rwkv_prefill),
    "hybrid": (hybrid_init, hybrid_apply, hybrid_decode, hybrid_cache_proto,
               hybrid_prefill),
}
