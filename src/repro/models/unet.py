"""U-Net (paper §4.2.2) as a heterogeneous pipeline program.

Architecture per the paper: 5 down-sampling and 5 up-sampling levels, B
convolution blocks between samplings, first-conv channels C doubling per
down level (halving per up level), "rather symmetric than the original
model ... for effective balancing".  Long skip connections tie each down
level's output to the matching up level — the paper's portal showcase.

GroupNorm replaces BatchNorm by default (paper §2 footnote 1: micro-batching
changes BN statistics; GN is micro-batch invariant, so pipelined results are
exactly sequential).  ``norm="batch"`` opts into the caveat for the tests
that demonstrate the discrepancy.

The model is expressed as a flat layer list (conv blocks, down, up, fuse)
with per-layer costs for torchgpipe.balance, then compiled into the
switch-based heterogeneous stage program (core/stage.py) whose stage
boundaries carry flat activation buffers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import balance as balance_lib
from repro.core.skip import SkipSpec


@dataclass(frozen=True)
class UNetConfig:
    B: int = 2                 # conv blocks between samplings (paper's B)
    C: int = 16                # first-conv output channels (paper's C)
    levels: int = 5
    in_ch: int = 3
    out_ch: int = 1
    img: int = 192
    norm: str = "group"        # group | batch (paper footnote-1 caveat)
    groups: int = 4


def conv_init(key, cin, cout, k=3, dtype=jnp.float32):
    w = jax.random.normal(key, (k, k, cin, cout)) * (k * k * cin) ** -0.5
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def conv_apply(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def norm_apply(p, x, cfg: UNetConfig):
    if cfg.norm == "group":
        N, H, W, C = x.shape
        g = min(cfg.groups, C)
        xg = x.reshape(N, H, W, g, C // g).astype(jnp.float32)
        mu = xg.mean((1, 2, 4), keepdims=True)
        var = xg.var((1, 2, 4), keepdims=True)
        xn = ((xg - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(x.shape)
    else:                       # "batch": statistics over the micro-batch
        x32 = x.astype(jnp.float32)
        mu = x32.mean((0, 1, 2), keepdims=True)
        var = x32.var((0, 1, 2), keepdims=True)
        xn = (x32 - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xn * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


@dataclass
class Layer:
    """One pipeline-visible layer of the sequentialized U-Net."""
    kind: str                  # block | down | up | head
    cin: int
    cout: int
    res: int                   # input spatial resolution
    skip_out: Optional[str] = None   # stash name (end of a down level)
    skip_in: Optional[str] = None    # pop name (start of an up level)

    def param_count(self) -> int:
        k = 9
        n = k * self.cin * self.cout + 2 * self.cout
        if self.kind == "up":
            n += 4 * self.cout * self.cout    # 2x2 transpose conv
        return n

    def flops(self) -> float:
        return 2.0 * 9 * self.cin * self.cout * self.res * self.res


def build_layers(cfg: UNetConfig) -> List[Layer]:
    layers: List[Layer] = []
    res = cfg.img
    ch = cfg.in_ch
    enc_ch = []
    for lvl in range(cfg.levels):
        cout = cfg.C * (2 ** lvl)
        for b in range(cfg.B):
            layers.append(Layer("block", ch, cout, res))
            ch = cout
        layers[-1] = dataclasses.replace(layers[-1], skip_out=f"s{lvl}")
        enc_ch.append(ch)
        layers.append(Layer("down", ch, cout * 2, res))
        ch = cout * 2
        res //= 2
    for lvl in reversed(range(cfg.levels)):
        cout = cfg.C * (2 ** lvl)
        layers.append(Layer("up", ch, cout, res, skip_in=f"s{lvl}"))
        res *= 2
        ch = cout + enc_ch[lvl]        # concat with the skip
        for b in range(cfg.B):
            layers.append(Layer("block", ch, cout, res))
            ch = cout
    layers.append(Layer("head", ch, cfg.out_ch, res))
    return layers


class UNetModel:
    """Layer list + params + per-layer apply; partitioned by balance."""

    def __init__(self, cfg: UNetConfig, n_stages: int,
                 balance_by: str = "flops"):
        self.cfg = cfg
        self.layers = build_layers(cfg)
        costs = [l.flops() if balance_by == "flops" else l.param_count()
                 for l in self.layers]
        self.sizes = balance_lib.block_partition(costs, n_stages)
        self.bounds = balance_lib.partition_bounds(self.sizes)
        self.n_stages = n_stages

    # ------------------------------------------------------------ parameters
    def init(self, key):
        params = []
        for i, l in enumerate(self.layers):
            k = jax.random.fold_in(key, i)
            # "up" layers first transpose-conv cin -> cout, then conv
            # cout -> cout; all other kinds conv cin -> cout.
            conv_cin = l.cout if l.kind == "up" else l.cin
            p = {"conv": conv_init(k, conv_cin, l.cout),
                 "norm": {"scale": jnp.ones((l.cout,), jnp.float32),
                          "bias": jnp.zeros((l.cout,), jnp.float32)}}
            if l.kind == "up":
                p["upconv"] = {
                    "w": (jax.random.normal(jax.random.fold_in(k, 1),
                                            (2, 2, l.cin, l.cout))
                          * (4 * l.cin) ** -0.5),
                    "b": jnp.zeros((l.cout,))}
            params.append(p)
        return params

    # ---------------------------------------------------------- layer apply
    def layer_apply(self, li: int, p, x, skips: Dict[str, Any]):
        l = self.layers[li]
        cfg = self.cfg
        if l.kind == "block":
            if l.skip_in:
                x = jnp.concatenate([x, skips.pop(l.skip_in)], axis=-1)
            y = jax.nn.relu(norm_apply(p["norm"], conv_apply(p["conv"], x),
                                       cfg))
            if l.skip_out:
                skips[l.skip_out] = y
            return y
        if l.kind == "down":
            y = conv_apply(p["conv"], x, stride=2)
            return jax.nn.relu(norm_apply(p["norm"], y, cfg))
        if l.kind == "up":
            N, H, W, C = x.shape
            y = jax.lax.conv_transpose(
                x, p["upconv"]["w"], (2, 2), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            y = y + p["upconv"]["b"]
            y = jax.nn.relu(norm_apply(p["norm"], conv_apply(p["conv"], y),
                                       cfg))
            skips[f"__up_{l.skip_in}"] = None   # marker (unused)
            y = jnp.concatenate([y, skips.pop(l.skip_in)], axis=-1)
            return y
        if l.kind == "head":
            return conv_apply(p["conv"], x)
        raise ValueError(l.kind)

    def apply_sequential(self, params, x):
        """Reference forward (no pipeline): exact oracle for tests."""
        skips: Dict[str, Any] = {}
        for i, p in enumerate(params):
            x = self.layer_apply(i, p, x, skips)
        return x

    # ---------------------------------------------------------- skip routing
    def skip_edges(self) -> List[SkipSpec]:
        """Portal edges implied by the stage partition."""
        stage_of = np.zeros(len(self.layers), int)
        for s in range(self.n_stages):
            stage_of[self.bounds[s]:self.bounds[s + 1]] = s
        edges = []
        produced = {}
        for i, l in enumerate(self.layers):
            if l.skip_out:
                produced[l.skip_out] = stage_of[i]
        for i, l in enumerate(self.layers):
            if l.kind == "up" and l.skip_in in produced:
                src, dst = produced[l.skip_in], stage_of[i]
                if dst > src:
                    edges.append(SkipSpec(l.skip_in, int(src), (int(dst),)))
        return edges

    def total_params(self) -> int:
        return sum(l.param_count() for l in self.layers)
