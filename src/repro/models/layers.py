"""Model primitives: norms, RoPE, attention, MLPs, MoE dispatch, SSM scan.

Per-layer *constants* (identity-pad mask, sliding-window size, causal flag,
cross-attention flag, ...) arrive as traced arrays sliced from a stacked
``[n_stages, L_per_stage]`` buffer — the stage program is SPMD-uniform, so
anything that varies per layer must be data, not Python structure.  All
masking paths therefore accept traced scalars.

Sharding constraints use :func:`tpc` (tensor-parallel constraint): they apply
only when the surrounding mesh actually has the named axes, so the same code
runs on a 1-device CPU smoke test and a 512-chip production mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import get_abstract_mesh
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, SSMConfig
from repro.kernels import ops

BATCH = ("pod", "data")
TP = "tp"


def constrain(x, spec: P):
    """with_sharding_constraint iff the current mesh has the spec's axes."""
    if compat.skip_constraints():
        return x
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)
    def ok(entry):
        if entry is None:
            return True
        if isinstance(entry, (tuple, list)):
            return all(e in names for e in entry)
        return entry in names
    if all(ok(e) for e in spec):
        return jax.lax.with_sharding_constraint(x, spec)
    return x


def act_bd(x):
    """Constrain [B, S, D]-like activations: batch over (pod, data)."""
    return constrain(x, P(BATCH, *([None] * (x.ndim - 1))))


def heads_tp(x):
    """Constrain [B, S, H, hd]: batch over (pod,data), heads over tp."""
    return constrain(x, P(BATCH, None, TP, None))


def ffn_tp(x):
    """Constrain [B, S, F]: hidden over tp."""
    return constrain(x, P(BATCH, None, TP))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, din: int, dout: int, dtype, scale: float = 1.0):
    std = scale * din ** -0.5
    return (jax.random.normal(key, (din, dout)) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    if kind == "rms":
        return ops.rmsnorm(x, p["scale"], eps)
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, pos, theta: float):
    """x: [B, S, H, hd]; pos: [S] or [B, S] (int)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if pos.ndim == 1:
        ang = pos.astype(jnp.float32)[:, None] * freq[None, :]      # [S, half]
        ang = ang[None, :, None, :]
    else:
        ang = pos.astype(jnp.float32)[..., None] * freq              # [B,S,half]
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (self / cross, train / decode)
# ---------------------------------------------------------------------------

def attn_init(key, d: int, a: AttentionConfig, dtype, *, out_scale=1.0):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, a.n_heads * a.head_dim, dtype),
        "wk": dense_init(ks[1], d, a.n_kv_heads * a.head_dim, dtype),
        "wv": dense_init(ks[2], d, a.n_kv_heads * a.head_dim, dtype),
        "wo": dense_init(ks[3], a.n_heads * a.head_dim, d, dtype, out_scale),
    }


def _qkv(p, x, kv_src, a: AttentionConfig):
    B, S, _ = x.shape
    Sk = kv_src.shape[1]
    q = heads_tp((x @ p["wq"]).reshape(B, S, a.n_heads, a.head_dim))
    k = heads_tp((kv_src @ p["wk"]).reshape(B, Sk, a.n_kv_heads, a.head_dim))
    v = heads_tp((kv_src @ p["wv"]).reshape(B, Sk, a.n_kv_heads, a.head_dim))
    return q, k, v


def attn_apply(p, x, a: AttentionConfig, *, memory=None, window=None,
               causal=None, pos=None, kv_len=None):
    """Full-sequence attention (train / prefill).

    window / causal / kv_len may be traced scalars (per-layer constants):
      window: 0 => unlimited;  causal: {0,1};  kv_len: valid key prefix.
    """
    B, S, D = x.shape
    kv_src = memory if memory is not None else x
    q, k, v = _qkv(p, x, kv_src, a)
    if a.use_rope and memory is None:
        pq = jnp.arange(S) if pos is None else pos
        q = rope(q, pq, a.rope_theta)
        k = rope(k, pq, a.rope_theta)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    eff_causal = a.causal if causal is None else causal
    eff_window = window
    if eff_window is None and a.kind == "swa":
        eff_window = a.window
    out = ops.attention(qt, kt, vt, causal=eff_causal, window=eff_window,
                        kv_len=kv_len)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, a.n_heads * a.head_dim)
    return act_bd(out @ p["wo"])


def attn_decode(p, x, cache, a: AttentionConfig, *, window=None,
                cross: bool = False):
    """One-token decode against a ring cache.

    x: [B, 1, D]; cache: {"k","v": [B, slots, Hkv, hd], "len": scalar int32}.
    The cache is a ring over ``slots``; the new KV pair lands at
    ``len % slots``.  Validity is computed from ring *distance* so the same
    code serves full attention (slots >= seq), uniform SWA (slots == window)
    and mixed per-layer traced windows (slots >= window, older entries
    masked).  For cross-attention the cache holds precomputed memory K/V and
    is not updated (valid prefix = cache["len"]).
    Returns (out [B, 1, D], new_cache).
    """
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, 1, a.n_heads, a.head_dim)
    ln = cache["len"]
    slots = cache["k"].shape[1]
    ki = jnp.arange(slots)
    if not cross:
        k1 = (x @ p["wk"]).reshape(B, 1, a.n_kv_heads, a.head_dim)
        v1 = (x @ p["wv"]).reshape(B, 1, a.n_kv_heads, a.head_dim)
        if a.use_rope:
            posv = jnp.full((B, 1), ln, jnp.int32)
            q = rope(q, posv, a.rope_theta)
            k1 = rope(k1, posv, a.rope_theta)
        slot = ln % slots
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k1.astype(cache["k"].dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v1.astype(cache["v"].dtype), slot, 1)
        new_cache = {"k": ck, "v": cv, "len": ln + 1}
        dist = (slot - ki) % slots          # 0 = newest, 1 = previous, ...
        w_eff = slots if window is None else jnp.minimum(
            jnp.asarray(window, jnp.int32), slots)
        valid = (dist < w_eff) & (dist <= ln)
    else:
        if a.use_rope:
            q = rope(q, jnp.full((B, 1), ln, jnp.int32), a.rope_theta)
        ck, cv = cache["k"], cache["v"]
        new_cache = cache
        valid = ki < ln
    from repro.kernels.ref import _expand_kv, NEG_INF
    qt = q.transpose(0, 2, 1, 3).astype(jnp.float32) * a.head_dim ** -0.5
    kt = _expand_kv(ck.transpose(0, 2, 1, 3), a.n_heads).astype(jnp.float32)
    vt = _expand_kv(cv.transpose(0, 2, 1, 3), a.n_heads).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qt, kt)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", pw, vt).astype(x.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, a.n_heads * a.head_dim)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, act: str, dtype, *, out_scale=1.0):
    ks = jax.random.split(key, 3)
    if act in ("silu", "geglu"):
        return {"wg": dense_init(ks[0], d, f, dtype),
                "wu": dense_init(ks[1], d, f, dtype),
                "wd": dense_init(ks[2], f, d, dtype, out_scale)}
    return {"wu": dense_init(ks[0], d, f, dtype),
            "wd": dense_init(ks[1], f, d, dtype, out_scale)}


def mlp_apply(p, x, act: str):
    if act in ("silu", "geglu"):
        g = ffn_tp(x @ p["wg"])
        u = ffn_tp(x @ p["wu"])
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    else:
        h = jax.nn.gelu(ffn_tp(x @ p["wu"]))
    return act_bd(h @ p["wd"])


# ---------------------------------------------------------------------------
# MoE (top-k router + capacity dispatch; EP over the tp axis)
# ---------------------------------------------------------------------------

def moe_init(key, d: int, f: int, m: MoEConfig, dtype, *, out_scale=1.0):
    ks = jax.random.split(key, 4)
    E = m.n_experts
    std = d ** -0.5
    return {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, d, f)) * std).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, f)) * std).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, f, d)) * std * out_scale).astype(dtype),
    }


def _expert_constrain(x):
    """[G, E, C, D]-like: experts over tp (EP), groups over (pod, data)."""
    return constrain(x, P(BATCH, TP, *([None] * (x.ndim - 2))))


def moe_apply(p, x, m: MoEConfig, *, group_size: int = 512):
    """Capacity-factor token dispatch (Mesh-TF/GSPMD style, activation
    stationary): tokens stay data-sharded, experts are EP-sharded over ``tp``,
    the combine einsum contracts the expert axis (GSPMD inserts the
    reduction).  Tokens over capacity are dropped (standard top-k routing)."""
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    T = B * S
    g = max(1, min(group_size, T))
    while T % g:
        g -= 1
    G = T // g
    xt = x.reshape(G, g, D)
    cap = int(max(1, round(g * k * m.capacity_factor / E)))

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                    # [G, g, E]
    vals, idx = jax.lax.top_k(gates, k)                        # [G, g, k]
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)

    combine = jnp.zeros((G, g, E, cap), jnp.float32)
    counts = jnp.zeros((G, E), jnp.float32)
    for slot in range(k):
        e = idx[..., slot]
        oh = jax.nn.one_hot(e, E, dtype=jnp.float32)           # [G, g, E]
        pos_all = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos = jnp.sum(oh * pos_all, -1)                        # [G, g]
        keep = (pos < cap).astype(jnp.float32)
        counts = counts + jnp.sum(oh * keep[..., None], axis=1)
        ohc = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        combine = combine + (vals[..., slot] * keep)[..., None, None] \
            * (oh[..., :, None] * ohc[..., None, :])
    dispatch = (combine > 0).astype(x.dtype)                   # [G, g, E, cap]

    ein = _expert_constrain(jnp.einsum("gsec,gsd->gecd", dispatch,
                                       xt.astype(x.dtype)))
    h_g = _expert_constrain(jnp.einsum("gecd,edf->gecf", ein, p["wg"]))
    h_u = _expert_constrain(jnp.einsum("gecd,edf->gecf", ein, p["wu"]))
    h = jax.nn.silu(h_g) * h_u
    eo = _expert_constrain(jnp.einsum("gecf,efd->gecd", h, p["wd"]))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), eo)
    return act_bd(out.reshape(B, S, D)), logits


def moe_aux_loss(logits, m: MoEConfig):
    """Switch-style load-balancing auxiliary loss."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = gates.mean(axis=tuple(range(gates.ndim - 1)))
    top1 = jnp.argmax(gates, -1)
    ce = jax.nn.one_hot(top1, m.n_experts).mean(
        axis=tuple(range(gates.ndim - 1)))
    return m.n_experts * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Selective SSM (Mamba-style head group; hymba's SSM half)
# ---------------------------------------------------------------------------

def ssm_init(key, d: int, s: SSMConfig, dtype):
    H = s.n_heads or d // s.head_dim
    ks = jax.random.split(key, 5)
    return {
        "w_in": dense_init(ks[0], d, H * s.head_dim, dtype),
        "w_bc": dense_init(ks[1], d, H * 2 * s.state_dim, dtype),
        "w_dt": dense_init(ks[2], d, H, dtype),
        "a_log": jnp.zeros((H, s.state_dim), jnp.float32),
        "w_out": dense_init(ks[3], H * s.head_dim, d, dtype),
        "dskip": jnp.ones((H, 1), jnp.float32) * 0.1,
    }


def ssm_scan(p, x, s: SSMConfig, state0=None):
    """x: [B, S, D] -> (y [B, S, D], state [B, H, hd, N]).

    Linear recurrence h_t = exp(-softplus(dt_t) exp(a_log)) h_{t-1}
                           + dt_t * (x_t ⊗ B_t); y_t = (h_t · C_t) + D·x_t,
    evaluated with an associative scan over time (TPU-friendly log-depth)."""
    B, S, D = x.shape
    H = s.n_heads or D // s.head_dim
    hd, N = s.head_dim, s.state_dim
    xh = (x @ p["w_in"]).reshape(B, S, H, hd)
    bc = (x @ p["w_bc"]).reshape(B, S, H, 2 * N).astype(jnp.float32)
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32))    # [B,S,H]
    decay = jnp.exp(-dt[..., None] * jnp.exp(p["a_log"])[None, None])  # [B,S,H,N]
    inc = (dt[..., None, None] * xh.astype(jnp.float32)[..., :, None]
           * Bm[..., None, :])                                   # [B,S,H,hd,N]

    def combine(a, b):
        (da, ia), (db, ib) = a, b
        return da * db, ib + db * ia

    d_sc, i_sc = jax.lax.associative_scan(
        combine, (jnp.broadcast_to(decay[..., None, :], inc.shape), inc), axis=1)
    h = i_sc
    if state0 is not None:
        h = h + d_sc * state0[:, None]
    y = jnp.einsum("bshdn,bshn->bshd", h, Cm) \
        + xh.astype(jnp.float32) * p["dskip"][None, None]
    y = y.reshape(B, S, H * hd).astype(x.dtype)
    return act_bd(y @ p["w_out"]), h[:, -1]


def ssm_decode(p, x, state, s: SSMConfig):
    """One-step SSM decode. state: [B, H, hd, N]."""
    B = x.shape[0]
    H = s.n_heads or x.shape[-1] // s.head_dim
    hd, N = s.head_dim, s.state_dim
    xh = (x @ p["w_in"]).reshape(B, 1, H, hd)
    bc = (x @ p["w_bc"]).reshape(B, 1, H, 2 * N).astype(jnp.float32)
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32))
    decay = jnp.exp(-dt[..., None] * jnp.exp(p["a_log"])[None, None])[:, 0]
    inc = (dt[..., None, None] * xh.astype(jnp.float32)[..., :, None]
           * Bm[..., None, :])[:, 0]
    state = decay[..., None, :] * state + inc
    y = jnp.einsum("bhdn,bhn->bhd", state, Cm[:, 0]) \
        + xh.astype(jnp.float32)[:, 0] * p["dskip"][None]
    y = y.reshape(B, 1, H * hd).astype(x.dtype)
    return y @ p["w_out"], state
