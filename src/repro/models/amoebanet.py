"""AmoebaNet-D (sequentialized, as the paper's speed benchmark uses).

The paper benchmarks "our implementation of a sequential version of
AmoebaNet-D in PyTorch" at (L, F) = (18, 256): 18 cells with filter scale F,
reduction cells at 1/3 and 2/3 depth.  We implement a faithful-in-spirit
sequential cell: parallel separable-conv 3x3 / 5x5 and avg-pool branches
summed into the residual stream (the dominant compute pattern of the real
NAS cell), channel count doubling at each reduction.  What the benchmark
measures — throughput scaling of a deep conv net under (m, n) pipeline
configurations — depends on the cell's cost profile, not its exact wiring.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import balance as balance_lib


@dataclass(frozen=True)
class AmoebaConfig:
    L: int = 18                 # number of cells (paper: 18)
    F: int = 256                # filter scale (paper: 256)
    in_ch: int = 3
    img: int = 224
    n_classes: int = 1000


def _sep_init(key, cin, cout, k):
    k1, k2 = jax.random.split(key)
    return {
        # depthwise layout under HWIO + feature_group_count=cin: [k,k,1,cin]
        "dw": (jax.random.normal(k1, (k, k, 1, cin)) * (k * k) ** -0.5),
        "pw": (jax.random.normal(k2, (1, 1, cin, cout)) * cin ** -0.5),
    }


def _sep_apply(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["dw"], (stride, stride), "SAME", feature_group_count=x.shape[-1],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(
        y, p["pw"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@dataclass
class Cell:
    kind: str       # stem | normal | reduction | head
    cin: int
    cout: int
    res: int

    def param_count(self) -> int:
        if self.kind == "stem":
            return 9 * self.cin * self.cout
        if self.kind == "head":
            return self.cin * self.cout
        return (9 + 25) * self.cin + 2 * self.cin * self.cout + 2 * self.cout

    def flops(self) -> float:
        r = self.res * self.res
        if self.kind == "stem":
            return 2.0 * 9 * self.cin * self.cout * r
        if self.kind == "head":
            return 2.0 * self.cin * self.cout
        return 2.0 * r * ((9 + 25) * self.cin + 2 * self.cin * self.cout)


class AmoebaNetModel:
    """Layer-list model compatible with pipeline_hetero."""

    def __init__(self, cfg: AmoebaConfig, n_stages: int):
        self.cfg = cfg
        self.layers: List[Cell] = []
        res = cfg.img // 2
        ch = cfg.F // 4
        self.layers.append(Cell("stem", cfg.in_ch, ch, cfg.img))
        red = {cfg.L // 3, 2 * cfg.L // 3}
        for i in range(cfg.L):
            if i in red:
                self.layers.append(Cell("reduction", ch, ch * 2, res))
                ch *= 2
                res //= 2
            else:
                self.layers.append(Cell("normal", ch, ch, res))
        self.layers.append(Cell("head", ch, cfg.n_classes, res))
        costs = [c.flops() for c in self.layers]
        self.sizes = balance_lib.block_partition(costs, n_stages)
        self.bounds = balance_lib.partition_bounds(self.sizes)
        self.n_stages = n_stages

    def init(self, key):
        out = []
        for i, c in enumerate(self.layers):
            k = jax.random.fold_in(key, i)
            if c.kind == "stem":
                out.append({"w": jax.random.normal(k, (3, 3, c.cin, c.cout))
                            * (9 * c.cin) ** -0.5})
            elif c.kind == "head":
                out.append({"w": jax.random.normal(k, (c.cin, c.cout))
                            * c.cin ** -0.5})
            else:
                k3, k5, kp = jax.random.split(k, 3)
                stride = 2 if c.kind == "reduction" else 1
                out.append({
                    "s3": _sep_init(k3, c.cin, c.cout, 3),
                    "s5": _sep_init(k5, c.cin, c.cout, 5),
                    "pw": jax.random.normal(kp, (1, 1, c.cin, c.cout))
                    * c.cin ** -0.5,
                    "scale": jnp.ones((c.cout,)),
                })
        return out

    def layer_apply(self, i: int, p, x, skips: Dict[str, Any]):
        c = self.layers[i]
        if c.kind == "stem":
            return jax.nn.relu(jax.lax.conv_general_dilated(
                x, p["w"], (2, 2), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")))
        if c.kind == "head":
            pooled = x.mean(axis=(1, 2))
            return pooled @ p["w"]
        stride = 2 if c.kind == "reduction" else 1
        b3 = _sep_apply(p["s3"], x, stride)
        b5 = _sep_apply(p["s5"], x, stride)
        pool = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
            (1, stride, stride, 1), "SAME")
        bp = jax.lax.conv_general_dilated(
            pool, p["pw"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = (b3 + b5 + bp) * p["scale"]
        if c.kind == "normal":
            y = y + x
        return jax.nn.relu(y)

    def apply_sequential(self, params, x):
        skips: Dict[str, Any] = {}
        for i, p in enumerate(params):
            x = self.layer_apply(i, p, x, skips)
        return x

    def total_params(self) -> int:
        return sum(c.param_count() for c in self.layers)
