"""LM-family model assembly: params, stacked stages, embed/head, caches.

A model is a sequence of identical blocks (per family) plus an embedding
frontend and an LM head.  The blocks are stacked ``[n_stages, L_per_stage]``
for the pipeline (identity-padded per core.stage.pad_layout); embed and head
run *outside* the pipeline shard_map in plain GSPMD land (they are cheap
relative to the trunk and their parameters are FSDP/TP-sharded, replicated
over ``pipe``).

Encoder-decoder (whisper): encoder layers fill the leading stages, decoder
layers the trailing ones; the per-layer constant record carries
``causal``/``cross``/``dec_active`` flags and the encoder output reaches
every decoder stage through portal skip edges (paper §3.3.1) — the strongest
real use of portals among the assigned architectures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ParallelConfig, ShapeConfig
from repro.core import stage as stage_lib
from repro.core.skip import SkipSpec
from repro.models import blocks as B
from repro.models import layers as L


def _embed_lookup(table, tokens, dtype):
    """Token-embedding gather, upcast to fp32 around the take.

    XLA CPU's AllReducePromotion pass crashes ("Invalid binary instruction
    opcode copy") when promoting the bf16 all-reduce that the partitioner
    emits for the gather's scatter-add gradient on a vocab-sharded table.
    Routing the gather (and hence its transpose) through fp32 sidesteps the
    pass with negligible cost and better embedding-grad accumulation.
    """
    return jnp.take(table.astype(jnp.float32), tokens, axis=0).astype(dtype)


def sinusoidal(positions, d: int, dtype=jnp.float32):
    half = d // 2
    freq = jnp.exp(-jnp.arange(half) / (half - 1) * np.log(10000.0))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


@dataclass
class LMModel:
    arch: ArchConfig
    pcfg: ParallelConfig
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        a = self.arch
        self.total_layers = a.n_layers + a.enc_layers
        # interleaved schedules cut the model into pipe * v GLOBAL stages;
        # rank r hosts the v chunks {r, r + pipe, ...} (Megatron layout)
        self.n_stages = self.pcfg.pipe * self.pcfg.virtual_stages
        # balance-partitioned (pcfg.partition) or legacy uniform ceil layout
        self.layout = stage_lib.partition_layout(
            self.total_layers, self.n_stages, self.pcfg.partition or None)
        self.L_per_stage = self.layout.L_per_stage
        self.layer_mask = self.layout.mask          # np [n_stages, L]
        fam = B.FAMILIES[a.family]
        (self.block_init, self.block_apply, self.block_decode,
         self.block_cache_proto, self.block_prefill) = fam
        # encoder/decoder stage split (whisper): encoder layers come first.
        if a.is_encdec:
            self.enc_last_stage = self.layout.stage_of(a.enc_layers - 1)
            self.dec_first_stage = self.layout.stage_of(a.enc_layers) \
                if a.enc_layers < self.total_layers else self.n_stages
        else:
            self.enc_last_stage = self.dec_first_stage = -1

    # ------------------------------------------------------------------ params
    def init(self, key):
        a = self.arch
        ks = jax.random.split(key, self.total_layers + 3)
        layer_ps = [self.block_init(ks[i], a, self.dtype)
                    for i in range(self.total_layers)]
        stages = stage_lib.stack_layer_params(layer_ps, self.n_stages,
                                              self.pcfg.partition or None)
        emb = {"tok": (jax.random.normal(ks[-1], (a.vocab, a.d_model))
                       * a.d_model ** -0.5).astype(self.dtype)}
        head = {"norm": L.norm_init(a.d_model, a.norm, self.dtype)}
        if not a.tie_embeddings:
            head["w"] = (jax.random.normal(ks[-2], (a.d_model, a.vocab))
                         * a.d_model ** -0.5).astype(self.dtype)
        return {"embed": emb, "stages": stages, "head": head}

    # ------------------------------------------------------------ layer consts
    def consts(self) -> Dict[str, jnp.ndarray]:
        """Stacked [n_stages, L_per_stage] per-layer constants.

        Built per GLOBAL layer then scattered onto the (possibly
        balance-partitioned) slot grid; padding slots take the identity
        defaults (mask 0, causal 1, dec_active 1) so padded layers stay
        exact identities under any partition.
        """
        a = self.arch
        tl = self.total_layers
        window = np.zeros(tl, np.int32)
        causal = np.ones(tl, np.int32)
        cross = np.zeros(tl, np.float32)
        dec_active = np.ones(tl, np.float32)
        if a.attn is not None:
            if a.attn.global_layers:
                window[:] = a.attn.window
                for g in a.attn.global_layers:
                    if g < tl:
                        window[g] = B.GLOBAL_WINDOW
            elif a.attn.kind == "swa":
                window[:] = a.attn.window
        is_enc_last = np.zeros(tl, np.float32)
        is_dec_first = np.zeros(tl, np.float32)
        if a.is_encdec:
            causal[:a.enc_layers] = 0
            cross[a.enc_layers:tl] = 1.0
            dec_active[:a.enc_layers] = 0.0
            is_enc_last[a.enc_layers - 1] = 1.0
            is_dec_first[a.enc_layers] = 1.0
        sc = self.layout.scatter
        c = {
            "mask": jnp.asarray(self.layer_mask, jnp.float32),
            "window": jnp.asarray(sc(window, 0)),
            "causal": jnp.asarray(sc(causal, 1)),
            "cross": jnp.asarray(sc(cross, 0.0)),
            "dec_active": jnp.asarray(sc(dec_active, 1.0)),
            "is_enc_last": jnp.asarray(sc(is_enc_last, 0.0)),
            "is_dec_first": jnp.asarray(sc(is_dec_first, 0.0)),
        }
        return c

    # ------------------------------------------------------------------ skips
    def skips(self) -> List[SkipSpec]:
        """Whisper: memory from the last encoder stage to every decoder
        stage, plus the decoder token embeddings from stage 0 to the first
        decoder stage.  Empty when the enc->dec boundary falls inside one
        stage (no cross-stage skip needed)."""
        if not self.arch.is_encdec:
            return []
        edges = []
        dec_stages = tuple(d for d in range(self.dec_first_stage, self.n_stages)
                           if d > self.enc_last_stage)
        if dec_stages:
            edges.append(SkipSpec("mem", self.enc_last_stage, dec_stages))
        if self.dec_first_stage > 0:
            edges.append(SkipSpec("dec_in", 0, (self.dec_first_stage,)))
        return edges

    def skip_protos(self, mb: int, S: int):
        if not self.arch.is_encdec:
            return {}
        proto = jax.ShapeDtypeStruct((mb, S, self.arch.d_model), self.dtype)
        return {"mem": proto, "dec_in": proto}

    # ------------------------------------------------------------------ embed
    def embed_inputs(self, emb, batch) -> Dict[str, jnp.ndarray]:
        """batch -> fresh stage-0 input pytree [B, ...]."""
        a = self.arch
        if a.is_encdec:
            h = batch["frames"].astype(self.dtype)           # stub frontend
            S = h.shape[1]
            h = h + sinusoidal(jnp.arange(S), a.d_model, self.dtype)[None]
            dec = jnp.take(emb["tok"], batch["dec_tokens"], axis=0)
            dec = dec + sinusoidal(jnp.arange(dec.shape[1]), a.d_model,
                                   self.dtype)[None]
            return {"h": L.act_bd(h), "dec_h": L.act_bd(dec)}
        h = _embed_lookup(emb["tok"], batch["tokens"], self.dtype)
        if a.name.startswith("gemma"):
            h = h * jnp.asarray(a.d_model ** 0.5, self.dtype)
        if a.frontend == "vision_stub" and "patches" in batch:
            p = batch["patches"].astype(self.dtype)
            np_ = min(p.shape[1], h.shape[1])    # patch tokens replace prefix
            h = jnp.concatenate([p[:, :np_], h[:, np_:]], axis=1)
        return {"h": L.act_bd(h)}

    def embed_decode(self, emb, tokens, pos):
        """Embed one decode token at absolute position ``pos``."""
        a = self.arch
        h = _embed_lookup(emb["tok"], tokens, self.dtype)
        if a.name.startswith("gemma"):
            h = h * jnp.asarray(a.d_model ** 0.5, self.dtype)
        if (a.is_encdec or not (a.attn and a.attn.use_rope)) \
                and a.family != "ssm":
            h = h + sinusoidal(jnp.asarray(pos)[None], a.d_model,
                               self.dtype)[None]
        return L.act_bd(h.astype(self.dtype))

    # ---------------------------------------------- stage fn (train / prefill)
    def make_stage_apply(self, consts, *, prefill: bool = False):
        """stage_apply for the pipeline runner.

        Encoder-decoder logic is uniform across all pipe/stage splits: the
        layer scan carries (h, mem, dec_emb); per-layer constants switch the
        carry from encoder hidden to decoder embeddings at ``is_dec_first``
        and latch the encoder output into ``mem`` at ``is_enc_last``.  Across
        stages, ``mem``/``dec_emb`` arrive through portal skip edges.
        """
        model = self
        a = model.arch

        def stage_apply(stage_params, carry, skips_in, resident, ctx):
            h = carry["h"]
            h = jnp.where(ctx.stage == 0, ctx.fresh["h"], h)
            if a.is_encdec:
                dec_emb = skips_in.get("dec_in", ctx.fresh.get("dec_h"))
                if dec_emb is None:
                    dec_emb = ctx.fresh["dec_h"]
                if "dec_in" in skips_in:
                    dec_emb = jnp.where(ctx.stage == 0, ctx.fresh["dec_h"],
                                        dec_emb)
                mem = skips_in.get("mem", jnp.zeros_like(h))
            else:
                dec_emb = None
                mem = None
            c_local = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, ctx.stage, 0, keepdims=False), consts)

            def body(carry_t, xs):
                h, mem, dec_emb = carry_t
                if prefill:
                    lp, c, cache = xs
                else:
                    lp, c = xs
                if a.is_encdec:
                    h = jnp.where(c["is_dec_first"] > 0, dec_emb, h)
                if prefill:
                    h2, cache = model.block_prefill(lp, h, c, a, cache,
                                                    memory=mem)
                else:
                    apply = model.block_apply
                    if model.pcfg.remat_layers:
                        apply = jax.checkpoint(
                            lambda lp_, h_, c_: model.block_apply(
                                lp_, h_, c_, a, memory=mem))
                        h2 = apply(lp, h, c)
                    else:
                        h2 = apply(lp, h, c, a, memory=mem)
                if a.is_encdec:
                    mem = jnp.where(c["is_enc_last"] > 0, h2, mem)
                out = (h2, mem, dec_emb)
                return out, (cache if prefill else None)

            if prefill:
                cache_mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_index_in_dim(
                        x, ctx.micro, 1, keepdims=False), resident)
                (h, mem, _), caches_new = jax.lax.scan(
                    body, (h, mem, dec_emb), (stage_params, c_local, cache_mb))
                resident = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), ctx.micro, 1),
                    resident, caches_new)
            else:
                (h, mem, _), _ = jax.lax.scan(
                    body, (h, mem, dec_emb), (stage_params, c_local))

            skips_out = {}
            if a.is_encdec:
                if mem is not None:
                    skips_out["mem"] = (mem if mem is not None else h).astype(model.dtype)
                skips_out["dec_in"] = ctx.fresh["dec_h"]
                skips_out = {k: v for k, v in skips_out.items()
                             if any(s.name == k for s in model.skips())}
            return {"h": h}, skips_out, resident

        return stage_apply

    # ------------------------------------------------------ stage fn (decode)
    def make_stage_apply_decode(self, consts):
        model = self

        def stage_apply(stage_params, carry, skips_in, resident, ctx):
            a = model.arch
            h = carry["h"]                       # [mb, 1, D]
            h = jnp.where(ctx.stage == 0, ctx.fresh["h"], h)
            c_all = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, ctx.stage, 0, keepdims=False), consts)
            # caches for this micro-batch slot
            cache_mb = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, ctx.micro, 1, keepdims=False), resident)

            def body(hc, lp_c_cache):
                h = hc
                lp, c, cache = lp_c_cache
                h2, cache2 = model.block_decode(lp, h, c, a, cache)
                if a.is_encdec:
                    act = c["dec_active"]
                    h2 = jnp.where(act > 0, h2, h)
                    cache2 = jax.tree.map(
                        lambda new, old: jnp.where(act > 0, new, old),
                        cache2, cache)
                return h2, cache2

            h, caches_new = jax.lax.scan(
                lambda hh, xs: body(hh, xs),
                h, (stage_params, c_all, cache_mb))
            res_new = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), ctx.micro, 1),
                resident, caches_new)
            return {"h": h}, {}, res_new

        return stage_apply

    # --------------------------------------------------------------- head/loss
    def head_logits(self, params, h):
        a = self.arch
        hn = L.norm_apply(params["head"]["norm"], h, a.norm)
        w = params["head"].get("w")
        if w is None:
            # tied embeddings: the table is d_model-sharded (gather-safe for
            # the embedding lookup); for the head matmul re-constrain it to
            # vocab-over-tp (replicated over data) so the logits contraction
            # is local per chunk.  One cheap table reshard per step.
            emb = L.constrain(params["embed"]["tok"],
                              jax.sharding.PartitionSpec(None, L.TP))
            w = emb.T
        return hn @ w

    def head_loss(self, params, h, labels, *, chunk: int = 0):
        """Chunked softmax cross-entropy over the sequence (never
        materializes [B, S, V] for the full sequence).

        Chunking notes from the §Perf iterations: smaller chunks multiply
        the per-chunk fp32 dW all-reduce that the scan's gradient
        accumulator forces (64 chunks cost 107 GB/step at 100k vocab);
        unrolling the loop lets chunk logits coexist (101 GiB/device).
        chunk=512 with a scan is the measured sweet spot."""
        a = self.arch
        h = L.act_bd(h)
        Bsz, S, D = h.shape
        if chunk <= 0:
            chunk = 512
        c = min(chunk, S)
        while S % c:
            c -= 1
        nchunk = S // c
        hc = h.reshape(Bsz, nchunk, c, D).swapaxes(0, 1)
        lc = labels.reshape(Bsz, nchunk, c).swapaxes(0, 1)

        @jax.checkpoint
        def one(hx, lx):
            hx = L.constrain(hx, jax.sharding.PartitionSpec(
                L.BATCH, None, None))
            logits = self.head_logits(params, hx).astype(jnp.float32)
            logits = L.constrain(logits, jax.sharding.PartitionSpec(
                L.BATCH, None, L.TP))
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, lx[..., None], -1)[..., 0]
            return (logz - gold).sum()

        def body(acc, xs):
            hx, lx = xs
            return acc + one(hx, lx), None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
        return tot / (Bsz * S)

    # ----------------------------------------------------------------- caches
    def cache_protos(self, shape: ShapeConfig, n_micro: int):
        """Stacked resident cache protos [n_stages, L_per_stage, m, mb, ...]."""
        a = self.arch
        mb = shape.global_batch // n_micro
        slots_len = shape.seq_len + 64
        per_layer = self.block_cache_proto(a, mb, slots_len, self.dtype)

        def stack(p):
            return jax.ShapeDtypeStruct(
                (self.n_stages, self.L_per_stage, n_micro) + tuple(p.shape),
                p.dtype)
        return jax.tree.map(stack, per_layer)

    def init_cache(self, shape: ShapeConfig, n_micro: int, *, filled: bool):
        """Concrete zero caches; ``filled`` marks them as already holding
        ``seq_len`` tokens (the decode_* shapes' precondition)."""
        protos = self.cache_protos(shape, n_micro)

        def mk(p):
            z = jnp.zeros(tuple(p.shape), p.dtype)
            return z
        cache = jax.tree.map(mk, protos)
        if filled:
            cache = jax.tree.map(
                lambda x: (jnp.full_like(x, shape.seq_len)
                           if x.dtype == jnp.int32 and x.ndim == 3 else x),
                cache)
        return cache

    # ------------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        a = self.arch
        Bsz, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            if a.is_encdec:
                return {"frames": jax.ShapeDtypeStruct((Bsz, S, a.d_model), jnp.bfloat16),
                        "dec_tokens": jax.ShapeDtypeStruct((Bsz, S), i32),
                        "labels": jax.ShapeDtypeStruct((Bsz, S), i32)}
                # frontend stub: precomputed frame embeddings per assignment
            spec = {"tokens": jax.ShapeDtypeStruct((Bsz, S), i32),
                    "labels": jax.ShapeDtypeStruct((Bsz, S), i32)}
            if a.frontend == "vision_stub":
                spec["patches"] = jax.ShapeDtypeStruct((Bsz, 256, a.d_model),
                                                       jnp.bfloat16)
            return spec
        if shape.kind == "prefill":
            if a.is_encdec:
                return {"frames": jax.ShapeDtypeStruct((Bsz, S, a.d_model), jnp.bfloat16),
                        "dec_tokens": jax.ShapeDtypeStruct((Bsz, S), i32)}
            spec = {"tokens": jax.ShapeDtypeStruct((Bsz, S), i32)}
            if a.frontend == "vision_stub":
                spec["patches"] = jax.ShapeDtypeStruct((Bsz, 256, a.d_model),
                                                       jnp.bfloat16)
            return spec
        # decode: one token per sequence + resident caches
        return {"tokens": jax.ShapeDtypeStruct((Bsz, 1), i32)}
