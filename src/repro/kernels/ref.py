"""Pure-jnp oracles for every Pallas kernel (and the XLA fallback paths).

Two flavours of attention reference:

* :func:`mha_naive` — materializes the full [*, Sq, Sk] score matrix. The
  ground-truth oracle for tests; O(S^2) memory.
* :func:`mha_blocked` — lax.scan over key/value blocks with online softmax
  (the flash-attention recurrence in plain jnp).  Numerically equivalent,
  O(S·block) memory — this is the default XLA path on non-TPU backends and
  the one the dry-run lowers, so the roofline's memory term reflects a
  non-materializing attention just as the TPU Pallas kernel does.

GQA convention everywhere: q is [B, Hq, Sq, D]; k/v are [B, Hkv, Sk, D] with
Hq % Hkv == 0 (kv heads broadcast over Hq // Hkv query groups).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(k, hq):
    hkv = k.shape[1]
    if hkv == hq:
        return k
    assert hq % hkv == 0, f"Hq={hq} not a multiple of Hkv={hkv}"
    return jnp.repeat(k, hq // hkv, axis=1)


def attention_mask(sq: int, sk: int, *, causal: bool, window: int = 0,
                   q_offset: int = 0) -> jnp.ndarray:
    """[Sq, Sk] boolean mask. ``q_offset`` positions queries within the key
    timeline (decode: q_offset = cache_len)."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= ki <= qi
    if window and window > 0:
        m &= ki > qi - window
    return m


def mha_naive(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: int = 0, scale: Optional[float] = None):
    """Ground-truth attention oracle (materializes scores)."""
    B, Hq, Sq, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    k = _expand_kv(k, Hq)
    v = _expand_kv(v, Hq)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = attention_mask(Sq, k.shape[2], causal=causal, window=window,
                          q_offset=q_offset)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _block_mask(qi, ki, sk, *, causal, window, kv_len):
    """[bq?, bk] mask; ``causal``/``window``/``kv_len`` may be traced."""
    msk = ki < sk
    if causal is not None:
        c = jnp.asarray(causal, bool)
        msk &= jnp.where(c, ki <= qi, True)
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        msk &= jnp.where(w > 0, ki > qi - w, True)
    if kv_len is not None:
        msk &= ki < jnp.asarray(kv_len, jnp.int32)
    return msk


def _mha_blocked_fwd_pass(q, k, v, *, causal, window, q_offset, scale,
                          block_k, kv_len):
    B, Hq, Sq, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[2]
    bk = min(block_k, Sk)
    nb = -(-Sk // bk)
    pad = nb * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, Hq, nb, bk, -1).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hq, nb, bk, -1).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32) * scale
    qi = jnp.arange(Sq)[:, None] + q_offset

    def body(carry, inp):
        acc, m_prev, l_prev = carry
        kblk, vblk, bidx = inp
        ki = bidx * bk + jnp.arange(bk)[None, :]
        msk = _block_mask(qi, ki, Sk, causal=causal, window=window,
                          kv_len=kv_len)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk.astype(jnp.float32))
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Hq, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hq, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nb)))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _mha_blocked_core(q, k, v, causal, window, kv_len, q_offset, scale,
                      block_k):
    out, _ = _mha_blocked_fwd_pass(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale,
                                   block_k=block_k, kv_len=kv_len)
    return out


def _mha_core_fwd(q, k, v, causal, window, kv_len, q_offset, scale, block_k):
    out, lse = _mha_blocked_fwd_pass(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset, scale=scale,
                                     block_k=block_k, kv_len=kv_len)
    return out, (q, k, v, out, lse, causal, window, kv_len)


def _mha_core_bwd(q_offset, scale, block_k, res, do):
    """Flash-attention backward: re-materialize probabilities block-by-block
    (never the full [Sq, Sk] matrix) and accumulate dq; dk/dv per block."""
    q, k, v, out, lse, causal, window, kv_len = res
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    bk = min(block_k, Sk)
    nb = -(-Sk // bk)
    pad = nb * bk - Sk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
    kb = kp.reshape(B, Hq, nb, bk, -1).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, Hq, nb, bk, -1).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    outf = out.astype(jnp.float32)
    delta = jnp.sum(dof * outf, axis=-1)                      # [B,H,Sq]
    qi = jnp.arange(Sq)[:, None] + q_offset

    def body(dq, inp):
        kblk, vblk, bidx = inp
        ki = bidx * bk + jnp.arange(bk)[None, :]
        msk = _block_mask(qi, ki, Sk, causal=causal, window=window,
                          kv_len=kv_len)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf * scale,
                       kblk.astype(jnp.float32))
        s = jnp.where(msk[None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # [B,H,Sq,bk]
        dv_b = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vblk.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds,
                             kblk.astype(jnp.float32))
        dk_b = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
        return dq, (dk_b, dv_b)

    dq0 = jnp.zeros((B, Hq, Sq, D), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dk = dkb.transpose(1, 2, 0, 3, 4).reshape(B, Hq, nb * bk, -1)[:, :, :Sk]
    dv = dvb.transpose(1, 2, 0, 3, 4).reshape(B, Hq, nb * bk, -1)[:, :, :Sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


_mha_blocked_core.defvjp(_mha_core_fwd, _mha_core_bwd)


def mha_blocked(q, k, v, *, causal=True, window=None, q_offset: int = 0,
                scale: Optional[float] = None, block_k: int = 512,
                kv_len=None):
    """Flash-attention recurrence in plain jnp (scan over KV blocks) with a
    blocked custom VJP — O(S·block) memory in forward AND backward.

    ``causal``/``window``/``kv_len`` may be traced scalars (mixed per-layer
    attention layouts); GQA kv heads are broadcast.  ``window`` semantics:
    None or 0 => unlimited."""
    B, Hq, Sq, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    k = _expand_kv(k, Hq)
    v = _expand_kv(v, Hq)
    if isinstance(window, int) and window == 0:
        window = None
    if isinstance(causal, (bool, int)):
        causal = bool(causal)
    return _mha_blocked_core(q, k, v, causal, window, kv_len,
                             q_offset, scale, min(block_k, k.shape[2]))


def decode_attend(q, k_cache, v_cache, cache_len, *, window: int = 0,
                  scale: Optional[float] = None):
    """Single-token decode attention against a [B, Hkv, Smax, D] cache.

    Returns (out [B, Hq, 1, D], partial (num, max, denom)) — the partial
    triple supports cross-shard LSE combination when the cache's sequence
    dim is sharded (long-context decode; see layers.seq_sharded_decode).
    """
    B, Hq, _, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    k = _expand_kv(k_cache, Hq).astype(jnp.float32)
    v = _expand_kv(v_cache, Hq).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, k)
    ki = jnp.arange(k.shape[2])[None, None, None, :]
    valid = ki < cache_len.reshape(B, 1, 1, 1)
    if window and window > 0:
        valid &= ki >= cache_len.reshape(B, 1, 1, 1) - window
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    num = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    den = p.sum(-1)
    out = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
    return out, (num, m, den)


def lse_combine(partials):
    """Combine per-shard (num, max, denom) decode partials (sequence sharding)."""
    nums, ms, dens = zip(*partials)
    m = functools.reduce(jnp.maximum, ms)
    num = sum(n * jnp.exp(mm - m)[..., None] for n, mm in zip(nums, ms))
    den = sum(d * jnp.exp(mm - m) for d, mm in zip(dens, ms))
    return num / jnp.maximum(den, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) WKV recurrence
# ---------------------------------------------------------------------------

def wkv6(r, k, v, w, u, state0=None):
    """RWKV-6 recurrence, sequential oracle.

    Shapes: r/k/w [B, H, T, K]; v [B, H, T, V]; u [H, K]; state [B, H, K, V].
      out_t  = r_t · (state_t + u ⊙ k_t ⊗ v_t)
      state' = diag(w_t) state_t + k_t ⊗ v_t            (w data-dependent)
    Returns (out [B, H, T, V], state_T).
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    s0 = jnp.zeros((B, H, K, V), f32) if state0 is None else state0.astype(f32)

    def step(s, inp):
        rt, kt, vt, wt = inp            # [B,H,K],[B,H,K],[B,H,V],[B,H,K]
        kv = kt[..., :, None] * vt[..., None, :]         # [B,H,K,V]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = (r.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), w.transpose(2, 0, 1, 3))
    sT, out = jax.lax.scan(step, s0, xs)
    return out.transpose(1, 2, 0, 3), sT


def wkv6_chunked(r, k, v, w, u, state0=None, *, chunk: int = 64):
    """Chunked WKV-6: O(T/C) sequential steps, O(C^2) parallel intra-chunk.

    This is the algorithm the Pallas kernel implements (DESIGN.md: TPU-native
    chunked linear attention instead of the CUDA per-timestep kernel):
      within a chunk, out_t = r_t · (A_t ⊙ S_in) + Σ_{s<=t} decay(s..t) terms
    using cumulative log-decay products.
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, f"T={T} not divisible by chunk={C}"
    n = T // C
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    s0 = jnp.zeros((B, H, K, V), f32) if state0 is None else state0.astype(f32)

    logw = jnp.log(jnp.maximum(w, 1e-30)).reshape(B, H, n, C, K)
    rc = r.reshape(B, H, n, C, K)
    kc = k.reshape(B, H, n, C, K)
    vc = v.reshape(B, H, n, C, V)

    # cumulative decays within chunk: cum[t] = sum_{s<=t} logw[s]
    cum = jnp.cumsum(logw, axis=3)                       # [B,H,n,C,K]
    total = cum[..., -1, :]                              # [B,H,n,K]

    def chunk_step(s, inp):
        rC, kC, vC, cumC, totC, logwC = inp              # [B,H,C,K]...
        # inter-chunk: queries see carried state decayed by cum_{t-1}
        decay_q = jnp.exp(cumC - logwC)                  # prod_{s<t} w_s (exclusive)
        inter = jnp.einsum("bhck,bhkv->bhcv", rC * decay_q, s)
        # intra-chunk: pair (s_idx <= t_idx) with decay prod_{s_idx<j<=?}:
        #   contribution of key step i to query step t>i: exp(cum_{t-1}-cum_i)
        qd = cumC - logwC                                # cum_{t-1}
        kd = cumC                                        # cum_i
        att = jnp.einsum("bhctk->bhct",
                         (rC[:, :, :, None, :] * kC[:, :, None, :, :]
                          * jnp.exp(qd[:, :, :, None, :] - kd[:, :, None, :, :])))
        C_ = rC.shape[2]
        tri = jnp.tril(jnp.ones((C_, C_), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        # bonus (u) term: current-step k contributes via u, no decay
        bonus = jnp.einsum("bhck,bhck->bhc", rC, kC * u[None, :, None, :])
        intra = jnp.einsum("bhct,bhtv->bhcv", att, vC) \
            + bonus[..., None] * vC
        out = inter + intra
        # state update: s' = diag(prod w) s + sum_i (prod_{j>i} w_j) k_i v_i
        kdecay = jnp.exp(totC[:, :, None, :] - cumC)     # prod_{j>i} w_j
        s = jnp.exp(totC)[..., None] * s + jnp.einsum(
            "bhck,bhcv->bhkv", kC * kdecay, vC)
        return s, out

    xs = tuple(x.transpose(2, 0, 1, 3, 4) for x in (rc, kc, vc, cum,)) + \
        (total.transpose(2, 0, 1, 3), logw.transpose(2, 0, 1, 3, 4))
    sT, out = jax.lax.scan(chunk_step, s0, xs)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, T, V)
    return out, sT


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
