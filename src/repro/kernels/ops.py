"""jit'd dispatch wrappers around the Pallas kernels.

Dispatch policy:
  * TPU backend           -> Pallas kernels (compiled).
  * REPRO_PALLAS_INTERPRET=1 -> Pallas kernels in interpret mode (CPU tests).
  * otherwise (CPU dry-run / smokes) -> the blocked pure-jnp implementations
    from :mod:`repro.kernels.ref`, which share the kernels' algorithmic
    structure (no [S, S] materialization) so the dry-run roofline reflects
    the same memory behaviour the TPU kernel has.

The Pallas forwards are wrapped in ``jax.custom_vjp`` with backward passes
taken from the reference implementations' VJPs: the recurrences are linear
enough that XLA's fused backward of the blocked reference is already
MXU-shaped, and it keeps the oracle and the gradient definition identical.
(A hand-written dq/dk/dv Pallas backward is a further optimization hook; see
EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6 import wkv6_pallas


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "") == "1"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_pallas(q, k, v, causal, window, q_offset):
    return flash_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, interpret=_interpret())


def _attention_fwd(q, k, v, causal, window, q_offset):
    out = _attention_pallas(q, k, v, causal, window, q_offset)
    return out, (q, k, v)


def _attention_bwd(causal, window, q_offset, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.mha_blocked(q_, k_, v_, causal=causal,
                                           window=window, q_offset=q_offset),
        q, k, v)
    return vjp(g)


_attention_pallas.defvjp(_attention_fwd, _attention_bwd)


def attention(q, k, v, *, causal=True, window=None, q_offset: int = 0,
              kv_len=None):
    """GQA attention: q [B,Hq,S,D], k/v [B,Hkv,S,D] -> [B,Hq,S,D].

    ``causal``/``window``/``kv_len`` may be traced (mixed per-layer layouts);
    the Pallas kernel requires them static and handles the common uniform
    cases, the blocked-jnp path (same algorithm, blocked custom VJP) covers
    the rest."""
    static = (isinstance(causal, (bool, int))
              and (window is None or isinstance(window, int))
              and kv_len is None)
    if (_use_pallas() or _interpret()) and static:
        return _attention_pallas(q, k, v, bool(causal), int(window or 0),
                                 q_offset)
    return ref.mha_blocked(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, kv_len=kv_len)


# ---------------------------------------------------------------------------
# RWKV-6 WKV
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp)
def _wkv6_pallas_op(r, k, v, w, u, s0):
    return wkv6_pallas(r, k, v, w, u, s0, interpret=_interpret())


def _wkv6_fwd(r, k, v, w, u, s0):
    out = _wkv6_pallas_op(r, k, v, w, u, s0)
    return out, (r, k, v, w, u, s0)


def _wkv6_bwd(res, g):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(lambda *a: ref.wkv6(*a), r, k, v, w, u, s0)
    return vjp(g)


_wkv6_pallas_op.defvjp(_wkv6_fwd, _wkv6_bwd)


def wkv6(r, k, v, w, u, state0=None):
    """RWKV-6 recurrence. Returns (out [B,H,T,V], state [B,H,K,V])."""
    if state0 is None:
        B, H, _, K = r.shape
        state0 = jnp.zeros((B, H, K, v.shape[-1]), jnp.float32)
    if _use_pallas() or _interpret():
        return _wkv6_pallas_op(r, k, v, w, u, state0)
    return ref.wkv6(r, k, v, w, u, state0)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    if _use_pallas() or _interpret():
        from repro.kernels.rmsnorm import rmsnorm_pallas
        return rmsnorm_pallas(x, scale, eps, interpret=_interpret())
    return ref.rmsnorm(x, scale, eps)


# Re-exported conveniences used by the model layers
decode_attend = ref.decode_attend
lse_combine = ref.lse_combine
