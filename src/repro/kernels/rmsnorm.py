"""Fused RMSNorm as a Pallas TPU kernel.

Small but hot: the norm runs twice per block per token, and unfused it costs
three HBM passes (square-mean, rsqrt-scale, multiply).  The Pallas version
tiles rows into VMEM ([block_rows, d] per grid step) and does the whole
reduction + scale in one pass, fp32 accumulation, bf16 in/out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_CompilerParams = pallas_compiler_params()


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6, *, block_rows: int = 256,
                   interpret: bool = False):
    """x: [..., D]; scale: [D]."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(xf.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xf, scale)
    return out[:rows].reshape(shape)
