"""Blocked online-softmax attention (flash attention) as a Pallas TPU kernel.

TPU adaptation notes (DESIGN.md §2): the CUDA flash-attention kernel is a
warp-level tiling over SRAM; on TPU the same insight — never materialize the
[Sq, Sk] score matrix in HBM — maps onto a Pallas grid over
``(batch*heads, q_blocks, k_blocks)`` with the k-block axis innermost and
``arbitrary`` (sequential) semantics, VMEM BlockSpecs feeding the MXU with
(block_q × head_dim) @ (head_dim × block_k) tiles, and fp32 running-max /
running-sum accumulators held in VMEM scratch across k-block steps.  Block
shapes default to MXU-aligned 128/512 (hardware-aligned multiples of 128).

GQA is handled without materializing repeated KV: the kv BlockSpec index map
folds the query-head index down by the group size.

Supports causal masking, sliding windows (SWA), and a static ``q_offset`` so
the same kernel serves chunked prefill.  Fully-masked k-blocks are skipped
with ``pl.when`` (causal ⇒ ~2× fewer block visits; SWA ⇒ O(window) blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_CompilerParams = pallas_compiler_params()

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, q_offset: int,
            block_q: int, block_k: int, nk: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = qi * block_q + q_offset
    k_first = ki * block_k
    needed = jnp.bool_(True)
    if causal:
        needed &= k_first <= q_first + block_q - 1
    if window > 0:
        needed &= k_first + block_k - 1 > q_first - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # [bq, D]
        k = k_ref[0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0].astype(jnp.float32)                   # [bk, Dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        rows = q_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < sk
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 512,
                    interpret: bool = False):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D]; Hq % Hkv == 0."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    padq = (-Sq) % bq
    padk = (-Sk) % bk
    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, Dv)
    if padq:
        qf = jnp.pad(qf, ((0, 0), (0, padq), (0, 0)))
    if padk:
        kf = jnp.pad(kf, ((0, 0), (0, padk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, padk), (0, 0)))
    nq = qf.shape[1] // bq
    nk = kf.shape[1] // bk

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=bq, block_k=bk, nk=nk, sk=Sk)

    out = pl.pallas_call(
        kern,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, Dv), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, nq * bq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :Sq].reshape(B, Hq, Sq, Dv)
