"""Chunked RWKV-6 (Finch) WKV recurrence as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the reference CUDA wkv6 kernel is a
per-timestep serial loop with one thread block per (batch, head) — a shape
that wastes the MXU entirely.  The TPU-native formulation is *chunked linear
attention*: split time into chunks of C steps; within a chunk all
interactions become two (C×C)·(C×K) matmul families (MXU work), and only one
[K, V] state matrix is carried serially between chunks.  The carried state
lives in VMEM scratch across grid steps; the grid is
``(batch*heads, T // C)`` with the chunk axis sequential ("arbitrary").

Math (see kernels/ref.py::wkv6): with cum_t = Σ_{j<=t} log w_j per chunk,
  out_t  = r_t·(exp(cum_{t-1})·S_in)                        (inter-chunk)
         + Σ_{i<t} exp(cum_{t-1}-cum_i)(r_t·k_i) v_i        (intra-chunk)
         + (r_t·(u⊙k_t)) v_t                                 (bonus)
  S_out  = exp(cum_C)·S_in + Σ_i exp(cum_C-cum_i) k_i ⊗ v_i

All decay algebra is fp32; r/k/v/w may be bf16 in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_CompilerParams = pallas_compiler_params()


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
            state, *, chunk: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # [C, K]
    k = k_ref[0].astype(jnp.float32)          # [C, K]
    v = v_ref[0].astype(jnp.float32)          # [C, V]
    w = w_ref[0].astype(jnp.float32)          # [C, K]
    u = u_ref[0].astype(jnp.float32)          # [K]

    logw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(logw, axis=0)            # [C, K] inclusive
    qdecay = jnp.exp(cum - logw)              # exp(cum_{t-1}) (exclusive)
    kdecay_in = jnp.exp(-cum)                 # exp(-cum_i)
    total = cum[-1]                           # [K]

    s_in = state[...]                         # [K, V]
    # inter-chunk term
    inter = jax.lax.dot_general(r * qdecay, s_in, (((1,), (0,)), ((), ())))
    # intra-chunk: att[t, i] = sum_k r_t q decay / k decay — computed as
    # (r*qdecay) @ (k*kdecay_in)^T, valid for i < t (strict lower triangle).
    att = jax.lax.dot_general(r * qdecay, k * kdecay_in,
                              (((1,), (1,)), ((), ())))    # [C, C]
    C = chunk
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.where(si < ti, att, 0.0)
    intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())))
    bonus = jnp.sum(r * k * u[None, :], axis=1, keepdims=True) * v
    o_ref[0] = (inter + intra + bonus).astype(o_ref.dtype)

    # state update
    kout = k * jnp.exp(total[None, :] - cum)  # exp(cum_C - cum_i) k_i
    state[...] = jnp.exp(total)[:, None] * s_in + jax.lax.dot_general(
        kout, v, (((0,), (0,)), ((), ())))

    @pl.when(ci == nc - 1)
    def _fin():
        sT_ref[0] = state[...].astype(sT_ref.dtype)


def wkv6_pallas(r, k, v, w, u, state0=None, *, chunk: int = 64,
                interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/w: [B, H, T, K]; v: [B, H, T, V]; u: [H, K]; state0: [B, H, K, V].

    Returns (out [B, H, T, V], state_T [B, H, K, V]).  The intra-chunk decay
    algebra divides by exp(cum_i); keep w bounded away from 0 (RWKV-6's decay
    parameterization w = exp(-exp(x)) does) or reduce ``chunk``.
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, f"T={T} % chunk={C} != 0"
    nc = T // C
    BH = B * H

    rf = r.reshape(BH, T, K)
    kf = k.reshape(BH, T, K)
    vf = v.reshape(BH, T, V)
    wf = w.reshape(BH, T, K)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(BH, K)
    s0 = (jnp.zeros((BH, K, V), jnp.float32) if state0 is None
          else state0.reshape(BH, K, V).astype(jnp.float32))

    kern = functools.partial(_kernel, chunk=C, nc=nc)
    out, sT = pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, C, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, C, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, C, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, C, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, K, V), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K, V), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)
    return out.reshape(B, H, T, V), sT.reshape(B, H, K, V)
