"""Quickstart: GPipe micro-batch pipeline parallelism in ~40 lines.

Builds a small llama-style LM, wraps it in the pipeline transform, and
trains a few steps on synthetic data.  On this CPU container the mesh is
1 device (the same code drives the 512-chip production mesh — see
repro/launch/dryrun.py).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim


def main():
    arch = configs.smoke_arch("smollm-360m")   # reduced dims, same family
    pcfg = configs.smoke_parallel("smollm-360m")
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    shape = ShapeConfig("train", seq_len=32, global_batch=8, kind="train")

    params = model.init(jax.random.PRNGKey(0))
    ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=30)
    opt = optim.init(ocfg, params)
    data = SyntheticLM(DataConfig(vocab=arch.vocab, seq_len=32,
                                  global_batch=8))

    with set_mesh(mesh):
        train_step = jax.jit(
            steps.build_train_step(model, pcfg, mesh, shape, ocfg))
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt, metrics = train_step(params, opt, batch)
            print(f"step {i}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
