"""Quickstart: GPipe micro-batch pipeline parallelism in ~40 lines.

Builds a small llama-style LM, asks the automatic planner for the
pipeline config (`ParallelConfig.auto` — schedule, microbatch count,
executor, and partition all chosen by the device model against the
hardware description), and trains a few steps on synthetic data.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ParallelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim
from repro.planner import HardwareSpec


def main():
    arch = configs.smoke_arch("smollm-360m")   # reduced dims, same family
    shape = ShapeConfig("train", seq_len=32, global_batch=8, kind="train")
    # one planner call replaces the manual five-knob dance (schedule,
    # n_micro, residuals, executor, partition); hardware.yaml in the repo
    # root shows the full schema for real slices
    hw = HardwareSpec(name="quickstart", ranks=len(jax.devices()),
                      memory_bytes=2.0 * 2**30)
    pcfg = ParallelConfig.auto(arch, shape, hw)
    print(f"planned: pipe={pcfg.pipe} schedule={pcfg.schedule} "
          f"m={pcfg.n_micro} executor={pcfg.executor}")
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)

    params = model.init(jax.random.PRNGKey(0))
    ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=30)
    opt = optim.init(ocfg, params)
    data = SyntheticLM(DataConfig(vocab=arch.vocab, seq_len=32,
                                  global_batch=8))

    with set_mesh(mesh):
        train_step = jax.jit(
            steps.build_train_step(model, pcfg, mesh, shape, ocfg))
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt, metrics = train_step(params, opt, batch)
            print(f"step {i}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
