"""Serving example: batched prefill + pipelined greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]

Uses the reduced config of the chosen architecture (MoE routing, SWA ring
caches, RWKV state, hybrid SSM state — whatever the family needs — all flow
through the same pipeline serve path).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    arch = configs.smoke_arch(args.arch)
    pcfg = configs.smoke_parallel(args.arch)
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(arch, pcfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    pshape = ShapeConfig("p", args.prompt_len, args.batch, "prefill")
    dshape = ShapeConfig("d", args.prompt_len + args.gen, args.batch,
                         "decode")
    with set_mesh(mesh):
        prefill = jax.jit(steps.build_prefill_step(model, pcfg, mesh, pshape))
        decode = jax.jit(steps.build_serve_step(model, pcfg, mesh, dshape))
        cache = model.init_cache(dshape, pcfg.n_micro, filled=False)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, arch.vocab)}
        if arch.is_encdec:
            batch = {"frames": jax.random.normal(
                key, (args.batch, args.prompt_len, arch.d_model)) * 0.1,
                "dec_tokens": batch["tokens"]}
        if arch.frontend == "vision_stub":
            batch["patches"] = jax.random.normal(
                key, (args.batch, 256, arch.d_model)) * 0.1

        logits, cache = prefill(params, cache, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        toks = np.concatenate([np.asarray(t) for t in out], 1)
        print(f"{arch.name}: generated {toks.shape} tokens in "
              f"{time.perf_counter()-t0:.2f}s; sample: {toks[0].tolist()}")


if __name__ == "__main__":
    main()
