"""Paper §3.3 in miniature: a pipelined U-Net whose long skip connections
are routed either THROUGH every intermediate stage (the symptomatic case)
or DIRECTLY via portals, verifying identical outputs and printing the
collective traffic of each compiled program.

Both modes lower to skip ROUTES in the unified schedule executor
(``run_pipeline_tasks``): the forward A/B runs a forward-only GPipe plan,
and the final section trains the portal model through the fused F+B
schedules — GPipe-tasked and 1F1B produce bitwise-identical losses and
gradients with the skip cotangents travelling the reverse routes.

    PYTHONPATH=src python examples/unet_portals.py
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ParallelConfig
from repro.launch import mesh as mesh_lib
from repro.models import pipeline_hetero as PH
from repro.models.unet import UNetConfig, UNetModel
from repro.roofline import analysis as RA


def main():
    cfg = UNetConfig(B=1, C=8, levels=4, img=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.img, cfg.img, 3))
    outs = {}
    for portals in (False, True):
        pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=4,
                              portals=portals, remat="full")
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        model = UNetModel(cfg, pcfg.pipe)
        params = model.init(jax.random.PRNGKey(0))
        prog = PH.build_hetero_program(model, params, 8 // pcfg.n_micro,
                                       pcfg, x[:2])
        with set_mesh(mesh):
            fwd = jax.jit(lambda xx: PH.hetero_forward(prog, mesh, pcfg, xx))
            y = fwd(x)
            cost = RA.analyze_hlo(fwd.lower(x).compile().as_text(), mesh.size)
        outs[portals] = np.asarray(y)
        mode = "portals " if portals else "threaded"
        print(f"{mode}: skip edges "
              f"{[(e.name, e.src_stage, e.dsts) for e in prog.skips]}, "
              f"boundary buffer {prog.carry_proto['buf'].shape}, "
              f"permute link bytes {cost.coll_link_bytes.get('collective-permute', 0):.3e}")
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-4, atol=2e-4)
    print("outputs identical — portals change the routing, not the math")

    # --- fused F+B schedules over the portal model -----------------------
    grads = {}
    for schedule in ("gpipe_tasked", "1f1b"):
        pcfg = ParallelConfig(pipe=4, tp=1, data=2, pod=1, n_micro=4,
                              portals=True, remat="full", schedule=schedule)
        mesh = mesh_lib.make_smoke_mesh(pcfg)
        model = UNetModel(cfg, pcfg.pipe)
        params = model.init(jax.random.PRNGKey(0))
        prog = PH.build_hetero_program(model, params, 8 // pcfg.n_micro,
                                       pcfg, x[:2])
        with set_mesh(mesh):
            tgt = jnp.zeros((8,) + tuple(prog.out_proto.shape[1:]))
            call = jax.jit(PH.hetero_grad_call(prog, mesh, pcfg))
            loss, g = call(prog.stacked_params, x, tgt)
        grads[schedule] = np.asarray(g)
        print(f"{schedule:>12}: loss {float(loss):.6f}, "
              f"grad norm {float(jnp.linalg.norm(g)):.6f}")
    np.testing.assert_array_equal(grads["gpipe_tasked"], grads["1f1b"])
    print("fused schedules bitwise-identical through the skip portals")


if __name__ == "__main__":
    main()
