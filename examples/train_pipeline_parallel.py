"""End-to-end driver example: ~100M-param model, multi-stage pipeline, a few
hundred steps with checkpoint/restart (deliverable (b): the train driver).

Runs a REAL 4-stage x 2-way-data pipeline on 8 XLA host devices — the same
execution path as the production mesh, scaled to this container.

    PYTHONPATH=src python examples/train_pipeline_parallel.py [--steps 200]
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, AttentionConfig, ParallelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_lib, steps
from repro.models.lm import LMModel
from repro.optim import optimizers as optim
from repro.planner import HardwareSpec
from repro.runtime.fault_tolerance import Supervisor, StepWatchdog

# ~100M params: a 12-layer, d=512 llama-style decoder with a 32k vocab
ARCH = ArchConfig(
    name="demo-100m", family="dense",
    n_layers=12, d_model=512, d_ff=2048, vocab=32000,
    attn=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=64),
    act="silu", norm="rms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_demo_ckpt")
    args = ap.parse_args()

    shape = ShapeConfig("train", args.seq_len, args.batch, "train")
    # the planner picks schedule / n_micro / residuals / executor /
    # partition against the hardware description; remaining knobs (data
    # parallelism, remat policy, portals) pass through as overrides.
    # executors=("spmd",): on emulated host-CPU devices the mpmd leg's
    # per-rank specialized compilation is not worth it
    pcfg = ParallelConfig.auto(
        ARCH, shape,
        HardwareSpec(name="demo-4", ranks=4, memory_bytes=4.0 * 2**30),
        executors=("spmd",), data=2, remat="full", portals=True)
    print(f"planned: schedule={pcfg.schedule} m={pcfg.n_micro} "
          f"residuals={pcfg.residuals} executor={pcfg.executor}")
    mesh = mesh_lib.make_smoke_mesh(pcfg)
    model = LMModel(ARCH, pcfg, dtype=jnp.float32)
    ocfg = optim.OptimizerConfig(lr=3e-4, warmup_steps=20,
                                 total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab=ARCH.vocab, seq_len=args.seq_len,
                                  global_batch=args.batch))
    print(f"model: {ARCH.total_params()/1e6:.0f}M params over "
          f"{pcfg.pipe} pipeline stages x {pcfg.data}-way data parallel, "
          f"m={pcfg.n_micro} micro-batches")

    with set_mesh(mesh):
        jstep = jax.jit(steps.build_train_step(model, pcfg, mesh, shape, ocfg))

    def make_state(restored):
        if restored is not None:
            return restored
        p = model.init(jax.random.PRNGKey(0))
        return {"params": p, "opt": optim.init(ocfg, p)}

    def step_fn(state, i):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        with set_mesh(mesh):
            p, o, m = jstep(state["params"], state["opt"], batch)
        if i % 10 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")
        return {"params": p, "opt": o}, {"loss": float(m["loss"])}

    sup = Supervisor(ckpt=CheckpointManager(args.ckpt_dir, keep=2),
                     make_state=make_state, step_fn=step_fn,
                     ckpt_every=50, watchdog=StepWatchdog())
    out = sup.run(args.steps)
    hist = [h["loss"] for h in out["history"]]
    print(f"done: loss {hist[0]:.4f} -> {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
